//! Executable fused W4A16 GEMM for the CPU host path (DESIGN.md §5).
//!
//! `kernels::splitk_launch` / `kernels::dp_launch` only *describe* the
//! paper's kernels for the simulator; this subsystem *runs* the same
//! decompositions in Rust:
//!
//! * [`fused_gemm_dp`] — one task per output tile, full k reduction
//!   (the data-parallel baseline, Fig. 2);
//! * [`fused_gemm_splitk`] — `split_k` k-slices across `std::thread`
//!   workers with private partial tiles and a deterministic tree
//!   reduction (the CPU analog of the paper's atomic adds, Fig. 1);
//! * [`fused_gemm_streamk`] — persistent-worker spans over the
//!   flattened `(n-tile × k-slice)` iteration space with a
//!   deterministic boundary-tile fixup merge (the paper's §4
//!   future-work direction, executable).
//!
//! Both unpack int4 nibbles from the packed `i32` words inside the inner
//! loop — no dense `f32[k, n]` weight is ever materialized — and reuse
//! the existing [`TileConfig`] / [`GemmShape`](super::GemmShape) /
//! [`Decomposition`] vocabulary so the autotuner can sweep real
//! wall-clock times next to simulated ones
//! ([`autotune_split_k_host`](super::autotune_split_k_host)).
//!
//! `quant::w4a16_gemm_ref` stays the naive correctness oracle; the
//! property tests in `rust/tests/property_tests.rs` pin this backend to
//! it.

mod dp;
mod fused;
mod splitk;
mod streamk;

pub use dp::{fused_gemm_dp, fused_gemm_dp_into};
pub use splitk::{fused_gemm_splitk, fused_gemm_splitk_into, SplitKScratch};
pub use streamk::{fused_gemm_streamk, fused_gemm_streamk_into};

use crate::gpusim::Decomposition;
use crate::quant::{quantize_weight, w4a16_gemm_ref, MatF32, QuantizedLinear,
                   PACK_FACTOR};
use crate::util::Rng;

use super::TileConfig;

/// Execution parameters of the host backend: tile geometry (reusing the
/// Triton-side [`TileConfig`]; `warps`/`stages` have no CPU meaning and
/// are ignored), the work decomposition (DP, SplitK × factor, or
/// StreamK × workers), and the worker-thread budget.
///
/// The decomposition and tile geometry define the *plan* — they fully
/// determine output bits. `threads` only budgets the OS threads that
/// execute the plan and can never change a result.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HostKernelConfig {
    pub tiles: TileConfig,
    /// Work decomposition (the plan half the autotuner searches).
    pub decomposition: Decomposition,
    /// Worker threads; 0 = one per available core.
    pub threads: usize,
}

impl HostKernelConfig {
    /// Cache-oriented default tile geometry for the host backend.
    pub fn host_tiles() -> TileConfig {
        TileConfig { block_m: 16, block_n: 64, block_k: 256, warps: 1, stages: 1 }
    }

    /// Data-parallel config (auto threads).
    pub fn dp() -> Self {
        HostKernelConfig {
            tiles: Self::host_tiles(),
            decomposition: Decomposition::DataParallel,
            threads: 0,
        }
    }

    /// SplitK config (auto threads).
    pub fn splitk(split_k: u32) -> Self {
        HostKernelConfig {
            tiles: Self::host_tiles(),
            decomposition: Decomposition::SplitK { split_k },
            threads: 0,
        }
    }

    /// StreamK config (`workers` persistent spans, auto threads).
    pub fn streamk(workers: u32) -> Self {
        HostKernelConfig {
            tiles: Self::host_tiles(),
            decomposition: Decomposition::StreamK { workers },
            threads: 0,
        }
    }

    /// Builder: replace the tile geometry.
    pub fn with_tiles(mut self, tiles: TileConfig) -> Self {
        self.tiles = tiles;
        self
    }

    /// Builder: pin the worker-thread count.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// The decomposition this config executes (normalized: a SplitK
    /// factor of 0 or 1 *is* the data-parallel reduction).
    pub fn decomposition(&self) -> Decomposition {
        match self.decomposition {
            Decomposition::SplitK { split_k } if split_k <= 1 => {
                Decomposition::DataParallel
            }
            d => d,
        }
    }

    /// The k-splitting factor (1 for DP and StreamK, whose k cuts are
    /// span-derived rather than a fixed factor).
    pub fn split_k(&self) -> u32 {
        match self.decomposition {
            Decomposition::SplitK { split_k } => split_k.max(1),
            _ => 1,
        }
    }

    /// StreamK span count (1 for the other decompositions).
    pub fn streamk_workers(&self) -> u32 {
        match self.decomposition {
            Decomposition::StreamK { workers } => workers.max(1),
            _ => 1,
        }
    }

    /// Compact sweep label, e.g. `splitk4/bn64/bk256/t8`.
    pub fn label(&self) -> String {
        format!("{}/bn{}/bk{}/t{}", self.decomposition().label(),
                self.tiles.block_n, self.tiles.block_k, self.threads)
    }

    /// Resolved worker count (0 ⇒ available cores).
    pub fn effective_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        }
    }

    /// Panic (like the reference path) on layout violations. The W4
    /// storage format guarantees these for any `quantize_weight` output;
    /// hand-built [`QuantizedLinear`]s are checked here.
    pub(crate) fn check_shapes(&self, a: &MatF32, q: &QuantizedLinear) {
        assert_eq!(a.cols, q.k, "activation k != weight k");
        assert_eq!(q.k % PACK_FACTOR, 0, "k must be a multiple of 8");
        assert_eq!(q.group_size % PACK_FACTOR, 0,
                   "group_size must be a multiple of 8");
        assert_eq!(q.k % q.group_size, 0, "k must be a multiple of group_size");
        assert_eq!(q.n % PACK_FACTOR, 0, "n must be a multiple of 8");
    }
}

/// Resize `out` to `rows × cols` (reallocating only on shape change)
/// and zero it — the shared store-not-accumulate contract of every
/// `*_into` executor entry point.
pub(crate) fn reset_output(out: &mut MatF32, rows: usize, cols: usize) {
    if out.rows != rows || out.cols != cols {
        *out = MatF32::zeros(rows, cols);
    } else {
        out.data.fill(0.0);
    }
}

/// Dispatch on the configured decomposition.
pub fn host_gemm(a: &MatF32, q: &QuantizedLinear,
                 cfg: &HostKernelConfig) -> MatF32 {
    let mut out = MatF32::zeros(a.rows, q.n);
    host_gemm_into(a, q, cfg, &mut SplitKScratch::new(), &mut out);
    out
}

/// [`host_gemm`] writing into a caller-owned output, reusing the
/// caller's [`SplitKScratch`] for slice partials. This is the decode
/// path's per-worker entry point: a step issues six-plus skinny GEMMs
/// back to back, and one scratch amortizes every SplitK partial
/// allocation across them. Bit-identical to [`host_gemm`].
pub fn host_gemm_into(a: &MatF32, q: &QuantizedLinear,
                      cfg: &HostKernelConfig,
                      scratch: &mut SplitKScratch, out: &mut MatF32) {
    match cfg.decomposition() {
        Decomposition::DataParallel => fused_gemm_dp_into(a, q, cfg, out),
        Decomposition::SplitK { .. } => {
            fused_gemm_splitk_into(a, q, cfg, scratch, out)
        }
        Decomposition::StreamK { .. } => {
            fused_gemm_streamk_into(a, q, cfg, scratch, out)
        }
    }
}

/// Batched multi-projection entry point: run one activation through
/// several same-shaped quantized layers (the decode step's fused
/// q/k/v projections), reusing a single scratch across all of them.
/// Equivalent to calling [`host_gemm`] per layer, bit for bit. An empty
/// layer list yields an empty result (never an index panic — callers
/// like the serving dispatcher must stay total in release builds).
pub fn host_gemm_multi(a: &MatF32, qs: &[&QuantizedLinear],
                       cfg: &HostKernelConfig,
                       scratch: &mut SplitKScratch) -> Vec<MatF32> {
    qs.iter()
        .map(|q| {
            let mut out = MatF32::zeros(a.rows, q.n);
            host_gemm_into(a, q, cfg, scratch, &mut out);
            out
        })
        .collect()
}

/// Startup self-check: run all three fused decompositions on a random
/// quantized layer and compare against the naive oracle. Returns the max
/// abs error observed, or an error if any variant drifts past `1e-3` —
/// the serving engine runs this before accepting traffic.
pub fn self_check(m: usize, nk: usize, group_size: usize)
                  -> Result<f32, String> {
    let group = group_size.max(PACK_FACTOR);
    if group % PACK_FACTOR != 0 {
        // Report invalid layouts as errors — this path exists to fail
        // loudly *without* panicking the engine thread.
        return Err(format!(
            "group_size {group} is not a multiple of {PACK_FACTOR} \
             (invalid W4 layout)"
        ));
    }
    let nk = nk.max(group).next_multiple_of(group);
    let m = m.max(1);
    let mut rng = Rng::seed_from(0xC0FFEE);
    let w = MatF32::new(nk, nk, rng.normal_vec(nk * nk, 0.05));
    let q = quantize_weight(&w, group);
    let a = MatF32::new(
        m, nk, (0..m * nk).map(|_| rng.uniform_f32(-1.0, 1.0)).collect());

    let want = w4a16_gemm_ref(&a, &q);
    let dp = fused_gemm_dp(&a, &q, &HostKernelConfig::dp());
    let sk = fused_gemm_splitk(&a, &q, &HostKernelConfig::splitk(4));
    let st = fused_gemm_streamk(&a, &q, &HostKernelConfig::streamk(4));
    let err = dp.max_abs_diff(&want)
        .max(sk.max_abs_diff(&want))
        .max(st.max_abs_diff(&want));
    if err > 1e-3 {
        return Err(format!(
            "fused host backend disagrees with w4a16_gemm_ref: \
             max |err| = {err:.3e} (m={m}, nk={nk}, group={group})"
        ));
    }
    Ok(err)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_constructors() {
        let dp = HostKernelConfig::dp();
        assert_eq!(dp.split_k(), 1);
        assert_eq!(dp.decomposition(), Decomposition::DataParallel);
        let sk = HostKernelConfig::splitk(4).with_threads(2);
        assert_eq!(sk.threads, 2);
        assert_eq!(sk.split_k(), 4);
        assert_eq!(sk.decomposition(), Decomposition::SplitK { split_k: 4 });
        // split 1 normalizes to the data-parallel reduction.
        assert_eq!(HostKernelConfig::splitk(1).decomposition(),
                   Decomposition::DataParallel);
        let st = HostKernelConfig::streamk(8);
        assert_eq!(st.streamk_workers(), 8);
        assert_eq!(st.split_k(), 1);
        assert_eq!(st.decomposition(), Decomposition::StreamK { workers: 8 });
        assert!(HostKernelConfig::dp().effective_threads() >= 1);
        assert_eq!(HostKernelConfig::streamk(4).with_threads(3).label(),
                   "streamk4/bn64/bk256/t3");
    }

    #[test]
    fn dispatch_routes_by_decomposition() {
        let mut rng = Rng::seed_from(30);
        let w = MatF32::new(64, 16, rng.normal_vec(64 * 16, 0.1));
        let q = quantize_weight(&w, 32);
        let a = MatF32::new(2, 64,
                            (0..128).map(|_| rng.uniform_f32(-1.0, 1.0)).collect());
        let want = w4a16_gemm_ref(&a, &q);
        for cfg in [HostKernelConfig::dp(), HostKernelConfig::splitk(2),
                    HostKernelConfig::streamk(3)] {
            let got = host_gemm(&a, &q, &cfg);
            assert!(got.max_abs_diff(&want) <= 1e-4, "{:?}", cfg.decomposition);
        }
    }

    #[test]
    fn multi_projection_matches_per_call_dispatch() {
        // host_gemm_multi with one shared scratch == independent
        // host_gemm calls, bit for bit, for both decompositions.
        let mut rng = Rng::seed_from(33);
        let k = 128;
        let qs: Vec<QuantizedLinear> = (0..3)
            .map(|_| {
                let w = MatF32::new(k, 32, rng.normal_vec(k * 32, 0.1));
                quantize_weight(&w, 32)
            })
            .collect();
        let a = MatF32::new(
            2, k, (0..2 * k).map(|_| rng.uniform_f32(-1.0, 1.0)).collect());
        let refs: Vec<&QuantizedLinear> = qs.iter().collect();
        for cfg in [HostKernelConfig::dp(), HostKernelConfig::splitk(4),
                    HostKernelConfig::streamk(4)] {
            let mut scratch = SplitKScratch::new();
            let got = host_gemm_multi(&a, &refs, &cfg, &mut scratch);
            assert_eq!(got.len(), 3);
            for (out, q) in got.iter().zip(&qs) {
                let want = host_gemm(&a, q, &cfg);
                assert_eq!(out.data, want.data);
            }
        }
    }

    #[test]
    fn multi_with_empty_layer_list_returns_empty() {
        // Regression: an empty projection list must yield an empty
        // result, not index into qs[0] (release builds skip
        // debug_asserts; totality here keeps the serving dispatcher
        // panic-free).
        let a = MatF32::new(1, 64, vec![0.5; 64]);
        let mut scratch = SplitKScratch::new();
        let got =
            host_gemm_multi(&a, &[], &HostKernelConfig::dp(), &mut scratch);
        assert!(got.is_empty());
    }

    #[test]
    fn measured_entry_point_allocates_no_partials_after_warmup() {
        // The autotuner times host_gemm_into with a persistent scratch
        // and output (one warmup call, then the measured runs). For the
        // k-splitting decompositions — the ones with partial-sum
        // buffers — the measured calls must allocate no partials, so
        // rankings don't charge serving steady state for allocator
        // noise it never pays. (DP has no partials; its per-tile stitch
        // buffers exist identically on the serving path, so its ranking
        // is steady-state-faithful too.)
        let mut rng = Rng::seed_from(35);
        let w = MatF32::new(256, 64, rng.normal_vec(256 * 64, 0.1));
        let q = quantize_weight(&w, 64);
        let a = MatF32::new(
            2, 256, (0..512).map(|_| rng.uniform_f32(-1.0, 1.0)).collect());
        // Narrow tiles so SplitK partials and StreamK fixups are both
        // genuinely multi-buffer.
        let tiles =
            TileConfig { block_m: 16, block_n: 16, block_k: 64, warps: 1, stages: 1 };
        for cfg in [HostKernelConfig::splitk(4), HostKernelConfig::streamk(4)] {
            let cfg = cfg.with_tiles(tiles);
            let mut scratch = SplitKScratch::new();
            let mut out = MatF32::zeros(a.rows, q.n);
            host_gemm_into(&a, &q, &cfg, &mut scratch, &mut out); // warmup
            let warm = scratch.alloc_events();
            assert!(warm > 0, "warmup must size the partial buffers");
            for _ in 0..3 {
                host_gemm_into(&a, &q, &cfg, &mut scratch, &mut out);
            }
            assert_eq!(scratch.alloc_events(), warm,
                       "{:?}: timed calls must reuse scratch", cfg.decomposition);
        }
    }

    #[test]
    fn gemm_into_resizes_output() {
        let mut rng = Rng::seed_from(34);
        let w = MatF32::new(64, 16, rng.normal_vec(64 * 16, 0.1));
        let q = quantize_weight(&w, 32);
        let a = MatF32::new(1, 64,
                            (0..64).map(|_| rng.uniform_f32(-1.0, 1.0)).collect());
        let mut out = MatF32::zeros(7, 3); // wrong shape on purpose
        let mut scratch = SplitKScratch::new();
        host_gemm_into(&a, &q, &HostKernelConfig::splitk(2), &mut scratch,
                       &mut out);
        assert_eq!((out.rows, out.cols), (1, 16));
        assert!(out.max_abs_diff(&w4a16_gemm_ref(&a, &q)) <= 1e-4);
    }

    #[test]
    fn self_check_passes_on_healthy_build() {
        let err = self_check(4, 96, 32).expect("self-check");
        assert!(err <= 1e-3);
    }

    #[test]
    fn self_check_rounds_shape_up() {
        // nk not a multiple of the group is rounded, not rejected.
        assert!(self_check(1, 100, 64).is_ok());
    }

    #[test]
    fn self_check_rejects_invalid_group() {
        // Invalid W4 layouts come back as Err, never a panic (this is
        // the engine-startup path).
        let err = self_check(1, 64, 12).unwrap_err();
        assert!(err.contains("group_size"));
    }
}
