//! Executable fused W4A16 GEMM for the CPU host path (DESIGN.md §5).
//!
//! `kernels::splitk_launch` / `kernels::dp_launch` only *describe* the
//! paper's kernels for the simulator; this subsystem *runs* the same
//! decompositions in Rust:
//!
//! * [`fused_gemm_dp`] — one task per output tile, full k reduction
//!   (the data-parallel baseline, Fig. 2);
//! * [`fused_gemm_splitk`] — `split_k` k-slices across `std::thread`
//!   workers with private partial tiles and a deterministic tree
//!   reduction (the CPU analog of the paper's atomic adds, Fig. 1);
//! * [`fused_gemm_streamk`] — persistent-worker spans over the
//!   flattened `(n-tile × k-slice)` iteration space with a
//!   deterministic boundary-tile fixup merge (the paper's §4
//!   future-work direction, executable).
//!
//! All three decompositions feed the register-blocked LUT micro-kernel
//! ([`microkernel`]): int4 nibbles are unpacked from the packed `i32`
//! words inside the inner loop — no dense `f32[k, n]` weight is ever
//! materialized — through a per-(group, column) 16-entry dequant LUT,
//! with `m_r × n_r` accumulator tiles in registers and, when the plan
//! says so ([`KernelLayout::Prepacked`]), a tile-major [`PackedLinear`]
//! weight copy whose k sweep is one contiguous stream. They reuse the
//! existing [`TileConfig`] / [`GemmShape`](super::GemmShape) /
//! [`Decomposition`] vocabulary so the autotuner can sweep real
//! wall-clock times next to simulated ones
//! ([`autotune_split_k_host`](super::autotune_split_k_host)).
//!
//! `quant::w4a16_gemm_ref` stays the naive correctness oracle; the
//! property tests in `rust/tests/property_tests.rs` pin this backend to
//! it.

mod dp;
mod fused;
mod layout;
mod microkernel;
mod splitk;
mod streamk;

pub use dp::{fused_gemm_dp, fused_gemm_dp_into};
pub use fused::{fused_gemm_legacy, fused_tile};
pub use layout::PackedLinear;
pub use splitk::{fused_gemm_splitk, fused_gemm_splitk_into, SplitKScratch};
pub use streamk::{fused_gemm_streamk, fused_gemm_streamk_into};

use std::sync::OnceLock;

use crate::gpusim::Decomposition;
use crate::quant::{quantize_weight, w4a16_gemm_ref, MatF32, QuantizedLinear,
                   PACK_FACTOR};
use crate::util::Rng;

use microkernel::WeightsRef;

use super::TileConfig;

/// Which weight storage an executor traverses.
///
/// The layout is *plan metadata*: both layouts compute bit-identical
/// results (the prepack is pure data movement — see [`PackedLinear`]),
/// so the autotuner sweeps it like any other knob and the serving plan
/// cache records the winner. `Flat` reads the canonical
/// [`QuantizedLinear`]; `Prepacked` expects the caller to supply a
/// [`PackedLinear`] via [`host_gemm_packed_into`] (entry points without
/// one simply run flat — the config is a preference, the entry point
/// the mechanism).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelLayout {
    /// Row-major `QuantizedLinear` storage (the artifact format).
    Flat,
    /// Tile-major [`PackedLinear`] panels, built once per (layer,
    /// `block_n`) and cached by the host model.
    Prepacked,
}

/// Execution parameters of the host backend: tile geometry (reusing the
/// Triton-side [`TileConfig`]; `warps`/`stages` have no CPU meaning and
/// are ignored), the work decomposition (DP, SplitK × factor, or
/// StreamK × workers), and the worker-thread budget.
///
/// The decomposition and tile geometry define the *plan* — they fully
/// determine output bits. `threads` only budgets the OS threads that
/// execute the plan and can never change a result.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HostKernelConfig {
    pub tiles: TileConfig,
    /// Work decomposition (the plan half the autotuner searches).
    pub decomposition: Decomposition,
    /// Worker threads; 0 = one per available core.
    pub threads: usize,
    /// Weight traversal layout (flat vs tile-major prepacked) — the
    /// third plan axis the autotuner sweeps. Bit-neutral by
    /// construction.
    pub layout: KernelLayout,
}

impl HostKernelConfig {
    /// Cache-oriented default tile geometry for the host backend.
    pub fn host_tiles() -> TileConfig {
        TileConfig { block_m: 16, block_n: 64, block_k: 256, warps: 1, stages: 1 }
    }

    /// Data-parallel config (auto threads).
    pub fn dp() -> Self {
        HostKernelConfig {
            tiles: Self::host_tiles(),
            decomposition: Decomposition::DataParallel,
            threads: 0,
            layout: KernelLayout::Flat,
        }
    }

    /// SplitK config (auto threads).
    pub fn splitk(split_k: u32) -> Self {
        HostKernelConfig {
            tiles: Self::host_tiles(),
            decomposition: Decomposition::SplitK { split_k },
            threads: 0,
            layout: KernelLayout::Flat,
        }
    }

    /// StreamK config (`workers` persistent spans, auto threads).
    pub fn streamk(workers: u32) -> Self {
        HostKernelConfig {
            tiles: Self::host_tiles(),
            decomposition: Decomposition::StreamK { workers },
            threads: 0,
            layout: KernelLayout::Flat,
        }
    }

    /// Builder: replace the tile geometry.
    pub fn with_tiles(mut self, tiles: TileConfig) -> Self {
        self.tiles = tiles;
        self
    }

    /// Builder: pin the worker-thread count.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Builder: select the weight traversal layout.
    pub fn with_layout(mut self, layout: KernelLayout) -> Self {
        self.layout = layout;
        self
    }

    /// True when the plan wants the tile-major prepacked traversal.
    pub fn prepacked(&self) -> bool {
        self.layout == KernelLayout::Prepacked
    }

    /// The decomposition this config executes (normalized: a SplitK
    /// factor of 0 or 1 *is* the data-parallel reduction).
    pub fn decomposition(&self) -> Decomposition {
        match self.decomposition {
            Decomposition::SplitK { split_k } if split_k <= 1 => {
                Decomposition::DataParallel
            }
            d => d,
        }
    }

    /// The k-splitting factor (1 for DP and StreamK, whose k cuts are
    /// span-derived rather than a fixed factor).
    pub fn split_k(&self) -> u32 {
        match self.decomposition {
            Decomposition::SplitK { split_k } => split_k.max(1),
            _ => 1,
        }
    }

    /// StreamK span count (1 for the other decompositions).
    pub fn streamk_workers(&self) -> u32 {
        match self.decomposition {
            Decomposition::StreamK { workers } => workers.max(1),
            _ => 1,
        }
    }

    /// Compact sweep label, e.g. `splitk4/bn64/bk256/t8` (with a `/pk`
    /// suffix when the plan uses the prepacked layout).
    pub fn label(&self) -> String {
        let pk = if self.prepacked() { "/pk" } else { "" };
        format!("{}/bn{}/bk{}/t{}{pk}", self.decomposition().label(),
                self.tiles.block_n, self.tiles.block_k, self.threads)
    }

    /// Resolved worker count (0 ⇒ available cores, via the process-wide
    /// [`available_cores`] cache).
    pub fn effective_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            available_cores()
        }
    }

    /// Panic (like the reference path) on layout violations. The W4
    /// storage format guarantees these for any `quantize_weight` output;
    /// hand-built [`QuantizedLinear`]s are checked here — including the
    /// *buffer dimensions* of all three packed tensors against
    /// `(k, n, group_size)`, since a short `qweight`/`scales`/`qzeros`
    /// would otherwise reach the kernels' unchecked hot-loop indexing.
    pub(crate) fn check_shapes(&self, a: &MatF32, q: &QuantizedLinear) {
        assert_eq!(a.cols, q.k, "activation k != weight k");
        assert_eq!(q.k % PACK_FACTOR, 0, "k must be a multiple of 8");
        assert_eq!(q.group_size % PACK_FACTOR, 0,
                   "group_size must be a multiple of 8");
        assert_eq!(q.k % q.group_size, 0, "k must be a multiple of group_size");
        assert_eq!(q.n % PACK_FACTOR, 0, "n must be a multiple of 8");
        let groups = q.k / q.group_size;
        assert_eq!((q.qweight.rows, q.qweight.cols),
                   (q.k / PACK_FACTOR, q.n),
                   "qweight buffer is not [k/8, n]");
        assert_eq!((q.scales.rows, q.scales.cols), (groups, q.n),
                   "scales buffer is not [k/group_size, n]");
        assert_eq!((q.qzeros.rows, q.qzeros.cols),
                   (groups, q.n / PACK_FACTOR),
                   "qzeros buffer is not [k/group_size, n/8]");
    }
}

/// Process-wide cached core count. `effective_threads()` used to query
/// `available_parallelism` on every GEMM dispatch — a syscall (cgroup
/// probing on Linux) on the decode loop's hottest path; one lookup per
/// process is enough, serving machines don't hot-swap CPUs.
pub fn available_cores() -> usize {
    static CORES: OnceLock<usize> = OnceLock::new();
    *CORES.get_or_init(|| {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// Resize `out` to `rows × cols` (reallocating only on shape change)
/// and zero it — the shared store-not-accumulate contract of every
/// `*_into` executor entry point.
pub(crate) fn reset_output(out: &mut MatF32, rows: usize, cols: usize) {
    if out.rows != rows || out.cols != cols {
        *out = MatF32::zeros(rows, cols);
    } else {
        out.data.fill(0.0);
    }
}

/// Dispatch on the configured decomposition.
pub fn host_gemm(a: &MatF32, q: &QuantizedLinear,
                 cfg: &HostKernelConfig) -> MatF32 {
    let mut out = MatF32::zeros(a.rows, q.n);
    host_gemm_into(a, q, cfg, &mut SplitKScratch::new(), &mut out);
    out
}

/// [`host_gemm`] writing into a caller-owned output, reusing the
/// caller's [`SplitKScratch`] for slice partials. This is the decode
/// path's per-worker entry point: a step issues six-plus skinny GEMMs
/// back to back, and one scratch amortizes every SplitK partial
/// allocation across them. Bit-identical to [`host_gemm`].
pub fn host_gemm_into(a: &MatF32, q: &QuantizedLinear,
                      cfg: &HostKernelConfig,
                      scratch: &mut SplitKScratch, out: &mut MatF32) {
    gemm_exec(a, WeightsRef::Flat(q), cfg, scratch, out);
}

/// [`host_gemm_into`] traversing a tile-major [`PackedLinear`] copy of
/// `q` instead of the flat layer — the entry point a
/// `layout: Prepacked` plan dispatches through. Bit-identical to the
/// flat path (the prepack is pure data movement; property tests pin
/// this), so callers may mix entry points freely. Panics if `pack` was
/// built from a layer of a different shape.
pub fn host_gemm_packed_into(a: &MatF32, q: &QuantizedLinear,
                             pack: &PackedLinear, cfg: &HostKernelConfig,
                             scratch: &mut SplitKScratch, out: &mut MatF32) {
    assert!(pack.matches(q),
            "prepacked layout shape mismatch: pack is [{}, {}] g{}, layer \
             is [{}, {}] g{}",
            pack.k, pack.n, pack.group_size, q.k, q.n, q.group_size);
    gemm_exec(a, WeightsRef::Packed { q, pack }, cfg, scratch, out);
}

/// Decomposition dispatch shared by the flat and prepacked entry points.
fn gemm_exec(a: &MatF32, wr: WeightsRef<'_>, cfg: &HostKernelConfig,
             scratch: &mut SplitKScratch, out: &mut MatF32) {
    match cfg.decomposition() {
        Decomposition::DataParallel => dp::dp_exec(a, wr, cfg, scratch, out),
        Decomposition::SplitK { .. } => {
            splitk::splitk_exec(a, wr, cfg, scratch, out)
        }
        Decomposition::StreamK { .. } => {
            streamk::streamk_exec(a, wr, cfg, scratch, out)
        }
    }
}

/// Batched multi-projection entry point: run one activation through
/// several same-shaped quantized layers, reusing a single scratch
/// across all of them. Equivalent to calling [`host_gemm`] per layer,
/// bit for bit. An empty layer list yields an empty result (never an
/// index panic — batched callers must stay total in release builds).
/// Flat-layout convenience; the serving dispatcher routes per layer
/// itself so each layer can use its cached prepacked copy.
pub fn host_gemm_multi(a: &MatF32, qs: &[&QuantizedLinear],
                       cfg: &HostKernelConfig,
                       scratch: &mut SplitKScratch) -> Vec<MatF32> {
    qs.iter()
        .map(|q| {
            let mut out = MatF32::zeros(a.rows, q.n);
            host_gemm_into(a, q, cfg, scratch, &mut out);
            out
        })
        .collect() // lint: allow(alloc): the output matrices themselves — callers own them
}

/// Startup self-check: run all three fused decompositions on a random
/// quantized layer and compare against the naive oracle. Returns the max
/// abs error observed, or an error if any variant drifts past `1e-3` —
/// the serving engine runs this before accepting traffic.
pub fn self_check(m: usize, nk: usize, group_size: usize)
                  -> Result<f32, String> {
    let group = group_size.max(PACK_FACTOR);
    if group % PACK_FACTOR != 0 {
        // Report invalid layouts as errors — this path exists to fail
        // loudly *without* panicking the engine thread.
        return Err(format!(
            "group_size {group} is not a multiple of {PACK_FACTOR} \
             (invalid W4 layout)"
        ));
    }
    let nk = nk.max(group).next_multiple_of(group);
    let m = m.max(1);
    let mut rng = Rng::seed_from(0xC0FFEE);
    let w = MatF32::new(nk, nk, rng.normal_vec(nk * nk, 0.05));
    let q = quantize_weight(&w, group);
    let a = MatF32::new(
        m, nk, (0..m * nk).map(|_| rng.uniform_f32(-1.0, 1.0)).collect());

    let want = w4a16_gemm_ref(&a, &q);
    let dp = fused_gemm_dp(&a, &q, &HostKernelConfig::dp());
    let sk = fused_gemm_splitk(&a, &q, &HostKernelConfig::splitk(4));
    let st = fused_gemm_streamk(&a, &q, &HostKernelConfig::streamk(4));
    let err = dp.max_abs_diff(&want)
        .max(sk.max_abs_diff(&want))
        .max(st.max_abs_diff(&want));
    if err > 1e-3 {
        return Err(format!(
            "fused host backend disagrees with w4a16_gemm_ref: \
             max |err| = {err:.3e} (m={m}, nk={nk}, group={group})"
        ));
    }
    Ok(err)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_constructors() {
        let dp = HostKernelConfig::dp();
        assert_eq!(dp.split_k(), 1);
        assert_eq!(dp.decomposition(), Decomposition::DataParallel);
        let sk = HostKernelConfig::splitk(4).with_threads(2);
        assert_eq!(sk.threads, 2);
        assert_eq!(sk.split_k(), 4);
        assert_eq!(sk.decomposition(), Decomposition::SplitK { split_k: 4 });
        // split 1 normalizes to the data-parallel reduction.
        assert_eq!(HostKernelConfig::splitk(1).decomposition(),
                   Decomposition::DataParallel);
        let st = HostKernelConfig::streamk(8);
        assert_eq!(st.streamk_workers(), 8);
        assert_eq!(st.split_k(), 1);
        assert_eq!(st.decomposition(), Decomposition::StreamK { workers: 8 });
        assert!(HostKernelConfig::dp().effective_threads() >= 1);
        assert_eq!(HostKernelConfig::streamk(4).with_threads(3).label(),
                   "streamk4/bn64/bk256/t3");
        // The layout axis: Flat by default, builder + label suffix.
        assert_eq!(dp.layout, KernelLayout::Flat);
        assert!(!dp.prepacked());
        let pk = HostKernelConfig::splitk(4)
            .with_threads(2)
            .with_layout(KernelLayout::Prepacked);
        assert!(pk.prepacked());
        assert_eq!(pk.label(), "splitk4/bn64/bk256/t2/pk");
    }

    #[test]
    fn available_cores_is_stable_and_positive() {
        let c = available_cores();
        assert!(c >= 1);
        // Cached: repeated lookups agree (and are now syscall-free).
        assert_eq!(c, available_cores());
    }

    #[test]
    fn dispatch_routes_by_decomposition() {
        let mut rng = Rng::seed_from(30);
        let w = MatF32::new(64, 16, rng.normal_vec(64 * 16, 0.1));
        let q = quantize_weight(&w, 32);
        let a = MatF32::new(2, 64,
                            (0..128).map(|_| rng.uniform_f32(-1.0, 1.0)).collect());
        let want = w4a16_gemm_ref(&a, &q);
        for cfg in [HostKernelConfig::dp(), HostKernelConfig::splitk(2),
                    HostKernelConfig::streamk(3)] {
            let got = host_gemm(&a, &q, &cfg);
            assert!(got.max_abs_diff(&want) <= 1e-4, "{:?}", cfg.decomposition);
        }
    }

    #[test]
    fn multi_projection_matches_per_call_dispatch() {
        // host_gemm_multi with one shared scratch == independent
        // host_gemm calls, bit for bit, for both decompositions.
        let mut rng = Rng::seed_from(33);
        let k = 128;
        let qs: Vec<QuantizedLinear> = (0..3)
            .map(|_| {
                let w = MatF32::new(k, 32, rng.normal_vec(k * 32, 0.1));
                quantize_weight(&w, 32)
            })
            .collect();
        let a = MatF32::new(
            2, k, (0..2 * k).map(|_| rng.uniform_f32(-1.0, 1.0)).collect());
        let refs: Vec<&QuantizedLinear> = qs.iter().collect();
        for cfg in [HostKernelConfig::dp(), HostKernelConfig::splitk(4),
                    HostKernelConfig::streamk(4)] {
            let mut scratch = SplitKScratch::new();
            let got = host_gemm_multi(&a, &refs, &cfg, &mut scratch);
            assert_eq!(got.len(), 3);
            for (out, q) in got.iter().zip(&qs) {
                let want = host_gemm(&a, q, &cfg);
                assert_eq!(out.data, want.data);
            }
        }
    }

    #[test]
    fn multi_with_empty_layer_list_returns_empty() {
        // Regression: an empty projection list must yield an empty
        // result, not index into qs[0] (release builds skip
        // debug_asserts; totality here keeps the serving dispatcher
        // panic-free).
        let a = MatF32::new(1, 64, vec![0.5; 64]);
        let mut scratch = SplitKScratch::new();
        let got =
            host_gemm_multi(&a, &[], &HostKernelConfig::dp(), &mut scratch);
        assert!(got.is_empty());
    }

    #[test]
    fn packed_layout_is_bit_identical_to_flat() {
        // host_gemm_packed_into == host_gemm_into, bit for bit, for all
        // three decompositions — including a pack whose panel width
        // differs from the executing tile geometry (the kernel segments
        // at panel boundaries internally).
        let mut rng = Rng::seed_from(36);
        let w = MatF32::new(192, 40, rng.normal_vec(192 * 40, 0.1));
        let q = quantize_weight(&w, 24);
        let a = MatF32::new(
            3, 192, (0..3 * 192).map(|_| rng.uniform_f32(-1.0, 1.0)).collect());
        let tiles =
            TileConfig { block_m: 16, block_n: 16, block_k: 64, warps: 1, stages: 1 };
        for cfg in [HostKernelConfig::dp(), HostKernelConfig::splitk(4),
                    HostKernelConfig::streamk(4)] {
            let cfg = cfg.with_tiles(tiles).with_threads(2);
            let mut want = MatF32::zeros(0, 0);
            host_gemm_into(&a, &q, &cfg, &mut SplitKScratch::new(), &mut want);
            for bn in [16usize, 7, 64] {
                let pack = PackedLinear::new(&q, bn);
                let mut got = MatF32::zeros(0, 0);
                host_gemm_packed_into(&a, &q, &pack, &cfg,
                                      &mut SplitKScratch::new(), &mut got);
                assert_eq!(want.data, got.data,
                           "{:?} bn={bn}", cfg.decomposition);
            }
        }
    }

    #[test]
    #[should_panic(expected = "prepacked layout shape mismatch")]
    fn packed_entry_rejects_mismatched_pack() {
        let mut rng = Rng::seed_from(37);
        let w = MatF32::new(64, 16, rng.normal_vec(64 * 16, 0.1));
        let q = quantize_weight(&w, 32);
        let other = quantize_weight(&MatF32::zeros(64, 24), 32);
        let pack = PackedLinear::new(&other, 8);
        let a = MatF32::new(1, 64, vec![0.5; 64]);
        let mut out = MatF32::zeros(0, 0);
        host_gemm_packed_into(&a, &q, &pack, &HostKernelConfig::dp(),
                              &mut SplitKScratch::new(), &mut out);
    }

    /// Regression (hand-built layers with short buffers): a truncated
    /// `qzeros` used to sail past `check_shapes` straight into the
    /// kernels' unchecked indexing; now every packed tensor's dimensions
    /// are validated against `(k, n, group_size)` up front.
    fn truncated_qzeros_layer() -> QuantizedLinear {
        let mut rng = Rng::seed_from(38);
        let w = MatF32::new(128, 16, rng.normal_vec(128 * 16, 0.1));
        let mut q = quantize_weight(&w, 32); // 4 groups
        // Keep only the first group's zero words: rows 4 -> 1.
        let kept: Vec<i32> = q.qzeros.data[..q.qzeros.cols].to_vec();
        q.qzeros = crate::quant::MatI32::new(1, q.qzeros.cols, kept);
        q
    }

    #[test]
    #[should_panic(expected = "qzeros buffer")]
    fn rejects_truncated_qzeros() {
        let q = truncated_qzeros_layer();
        let a = MatF32::new(1, 128, vec![0.5; 128]);
        let _ = host_gemm(&a, &q, &HostKernelConfig::splitk(2));
    }

    #[test]
    #[should_panic(expected = "scales buffer")]
    fn rejects_truncated_scales() {
        let mut rng = Rng::seed_from(39);
        let w = MatF32::new(64, 16, rng.normal_vec(64 * 16, 0.1));
        let mut q = quantize_weight(&w, 32);
        let kept: Vec<f32> = q.scales.data[..16].to_vec();
        q.scales = MatF32::new(1, 16, kept);
        let a = MatF32::new(1, 64, vec![0.5; 64]);
        let _ = host_gemm(&a, &q, &HostKernelConfig::dp());
    }

    #[test]
    #[should_panic(expected = "qweight buffer")]
    fn rejects_truncated_qweight() {
        let mut rng = Rng::seed_from(40);
        let w = MatF32::new(64, 16, rng.normal_vec(64 * 16, 0.1));
        let mut q = quantize_weight(&w, 32);
        let kept: Vec<i32> = q.qweight.data[..4 * 16].to_vec();
        q.qweight = crate::quant::MatI32::new(4, 16, kept);
        let a = MatF32::new(1, 64, vec![0.5; 64]);
        let _ = host_gemm(&a, &q, &HostKernelConfig::streamk(2));
    }

    #[test]
    fn measured_entry_point_allocates_no_partials_after_warmup() {
        // The autotuner times host_gemm_into with a persistent scratch
        // and output (one warmup call, then the measured runs). The
        // measured calls must allocate none of the scratch-tracked
        // buffers — SplitK partials, StreamK fixups, per-worker LUT/row
        // buffers, and DP's multi-worker stitch arenas — so rankings
        // don't charge serving steady state for allocator noise it
        // never pays. (Small per-call bookkeeping Vecs — tile lists,
        // worker handles — are not tracked by alloc_events and are the
        // known remainder.)
        let mut rng = Rng::seed_from(35);
        let w = MatF32::new(256, 64, rng.normal_vec(256 * 64, 0.1));
        let q = quantize_weight(&w, 64);
        let a = MatF32::new(
            2, 256, (0..512).map(|_| rng.uniform_f32(-1.0, 1.0)).collect());
        // Narrow tiles so SplitK partials and StreamK fixups are both
        // genuinely multi-buffer. DP rides along since its workers now
        // hold LUT/row buffers in the same scratch, and alloc_events()
        // folds those TileScratch growth events in.
        let tiles =
            TileConfig { block_m: 16, block_n: 16, block_k: 64, warps: 1, stages: 1 };
        for cfg in [HostKernelConfig::dp(), HostKernelConfig::splitk(4),
                    HostKernelConfig::streamk(4)] {
            let cfg = cfg.with_tiles(tiles);
            let mut scratch = SplitKScratch::new();
            let mut out = MatF32::zeros(a.rows, q.n);
            host_gemm_into(&a, &q, &cfg, &mut scratch, &mut out); // warmup
            let warm = scratch.alloc_events();
            assert!(warm > 0, "warmup must size the partial/LUT buffers");
            for _ in 0..3 {
                host_gemm_into(&a, &q, &cfg, &mut scratch, &mut out);
            }
            assert_eq!(scratch.alloc_events(), warm,
                       "{:?}: timed calls must reuse scratch", cfg.decomposition);
        }
    }

    #[test]
    fn prepacked_path_allocates_nothing_after_warmup() {
        // The LUT/prepack extension of the steady-state contract: with
        // the pack built up front (as the host model's warm() does), the
        // prepacked entry point must be allocation-free after one
        // warmup call too.
        let mut rng = Rng::seed_from(41);
        let w = MatF32::new(256, 64, rng.normal_vec(256 * 64, 0.1));
        let q = quantize_weight(&w, 64);
        let a = MatF32::new(
            1, 256, (0..256).map(|_| rng.uniform_f32(-1.0, 1.0)).collect());
        let tiles =
            TileConfig { block_m: 16, block_n: 16, block_k: 64, warps: 1, stages: 1 };
        let pack = PackedLinear::new(&q, tiles.block_n as usize);
        for cfg in [HostKernelConfig::dp(), HostKernelConfig::splitk(4),
                    HostKernelConfig::streamk(4)] {
            let cfg = cfg.with_tiles(tiles).with_layout(KernelLayout::Prepacked);
            let mut scratch = SplitKScratch::new();
            let mut out = MatF32::zeros(a.rows, q.n);
            host_gemm_packed_into(&a, &q, &pack, &cfg, &mut scratch, &mut out);
            let warm = scratch.alloc_events();
            assert!(warm > 0, "warmup must size the LUT buffers");
            for _ in 0..3 {
                host_gemm_packed_into(&a, &q, &pack, &cfg, &mut scratch,
                                      &mut out);
            }
            assert_eq!(scratch.alloc_events(), warm,
                       "{:?}: prepacked steady state must not allocate",
                       cfg.decomposition);
        }
    }

    #[test]
    fn gemm_into_resizes_output() {
        let mut rng = Rng::seed_from(34);
        let w = MatF32::new(64, 16, rng.normal_vec(64 * 16, 0.1));
        let q = quantize_weight(&w, 32);
        let a = MatF32::new(1, 64,
                            (0..64).map(|_| rng.uniform_f32(-1.0, 1.0)).collect());
        let mut out = MatF32::zeros(7, 3); // wrong shape on purpose
        let mut scratch = SplitKScratch::new();
        host_gemm_into(&a, &q, &HostKernelConfig::splitk(2), &mut scratch,
                       &mut out);
        assert_eq!((out.rows, out.cols), (1, 16));
        assert!(out.max_abs_diff(&w4a16_gemm_ref(&a, &q)) <= 1e-4);
    }

    #[test]
    fn self_check_passes_on_healthy_build() {
        let err = self_check(4, 96, 32).expect("self-check");
        assert!(err <= 1e-3);
    }

    #[test]
    fn self_check_rounds_shape_up() {
        // nk not a multiple of the group is rounded, not rejected.
        assert!(self_check(1, 100, 64).is_ok());
    }

    #[test]
    fn self_check_rejects_invalid_group() {
        // Invalid W4 layouts come back as Err, never a panic (this is
        // the engine-startup path).
        let err = self_check(1, 64, 12).unwrap_err();
        assert!(err.contains("group_size"));
    }
}
