//! SplitK host executor: the k reduction is cut into `split_k` slices
//! executed across worker threads, each accumulating a private partial
//! C, followed by a deterministic pairwise tree reduction — the CPU
//! analog of the paper's atomic-add merge (Fig. 1), but with a fixed
//! merge order so results are reproducible bit for bit.
//!
//! Why this wins on skinny shapes: at `m = 1` the data-parallel grid
//! degenerates into column-panel tasks whose packed-weight reads stride
//! by the full row pitch (`block_n · 4` useful bytes every `n · 4`), while
//! each SplitK worker streams its k-slice of `qweight` fully
//! sequentially with an L1-resident accumulator row — the same
//! "decomposition determines the memory behavior" story the paper tells
//! about SM occupancy, translated to cache/prefetcher behavior.

use crate::quant::{MatF32, QuantizedLinear, PACK_FACTOR};

use super::microkernel::{kernel_tile, TileScratch, WeightsRef};
use super::HostKernelConfig;

/// Reusable partial-sum buffers for the k-splitting executors
/// ([`fused_gemm_splitk_into`] slice partials and
/// [`fused_gemm_streamk_into`](super::fused_gemm_streamk_into) span
/// fixups), plus the per-worker micro-kernel scratches (dequant LUT
/// panels + row buffers) every decomposition's workers dequantize
/// through.
///
/// The SplitK executor needs `split_k` private `m × n` partial matrices
/// per call and StreamK one `m × block_n` contribution buffer per
/// span-tile descriptor; a decode step issues several skinny GEMMs back
/// to back, so callers on that path keep one scratch alive and amortize
/// the allocations (the buffers are zero-filled, never freshly
/// allocated, when shapes repeat). Reuse cannot change output bits:
/// buffers start at exactly `0.0` either way (and LUT panels are fully
/// rebuilt per group) and the accumulation/reduction order is
/// unchanged.
#[derive(Debug, Default)]
pub struct SplitKScratch {
    pub(crate) partials: Vec<MatF32>,
    /// StreamK span-contribution buffers (disjoint from `partials` so
    /// an autotune sweep alternating decompositions does not thrash
    /// either family's steady-state shapes).
    pub(crate) fixups: Vec<MatF32>,
    /// Per-worker micro-kernel scratches (LUT panel + row buffer), one
    /// per OS-thread slot, handed to scoped workers as disjoint `&mut`s.
    pub(crate) tile: Vec<TileScratch>,
    /// Per-worker DP stitch arenas: each multi-worker DP worker packs
    /// its private output-tile buffers into one grow-only arena
    /// (`dp.rs`), so the per-tile `vec![..]` the stitch used to pay on
    /// every call happens once at warmup. Growth is counted into the
    /// matching worker's [`TileScratch::allocs`].
    pub(crate) stitch: Vec<Vec<f32>>,
    /// Buffer (re)allocation events — see [`Self::alloc_events`].
    pub(crate) allocs: u64,
}

impl SplitKScratch {
    /// Empty scratch; buffers are sized lazily on first use.
    pub fn new() -> Self {
        SplitKScratch::default()
    }

    /// How many buffer allocations (fresh or reshaping) this scratch has
    /// performed so far — partial/fixup matrices *and* the micro-kernel
    /// LUT/row buffers. At a steady state — repeated calls with the
    /// same shape and config — the count must not grow after the first
    /// call: the serving decode loop and the autotuner's timed
    /// measurements both rely on the reused path being allocation-free.
    pub fn alloc_events(&self) -> u64 {
        self.allocs + self.tile.iter().map(|t| t.allocs).sum::<u64>()
    }

    /// Make sure at least `workers` micro-kernel scratches exist (their
    /// buffers are sized lazily inside the kernel).
    pub(crate) fn ensure_tile_scratches(&mut self, workers: usize) {
        while self.tile.len() < workers {
            self.tile.push(TileScratch::default());
        }
    }

    /// Make sure at least `workers` DP stitch arenas exist (sized
    /// lazily by the DP workers).
    pub(crate) fn ensure_stitch_arenas(&mut self, workers: usize) {
        while self.stitch.len() < workers {
            self.stitch.push(Vec::new());
        }
    }
}

/// Zero `buf` in place, reallocating (and counting the event in
/// `allocs`) only when the requested shape differs.
pub(crate) fn ensure_zeroed(buf: &mut MatF32, rows: usize, cols: usize,
                            allocs: &mut u64) {
    if buf.rows != rows || buf.cols != cols {
        *buf = MatF32::zeros(rows, cols);
        *allocs += 1;
    } else {
        buf.data.fill(0.0);
    }
}

/// Fused W4A16 GEMM, SplitK decomposition: `C = A @ dequant(Q)`.
///
/// Slice boundaries sit on packed-row (8-element) granularity, so any
/// `split_k` is legal — `k % split_k != 0` just makes the slices uneven
/// (±8 k elements), mirroring how the launch-descriptor side relaxes the
/// Triton kernel's divisibility constraint.
///
/// Results are identical for any worker-thread count: slice partials
/// depend only on `split_k`, and the reduction tree is fixed.
pub fn fused_gemm_splitk(a: &MatF32, q: &QuantizedLinear,
                         cfg: &HostKernelConfig) -> MatF32 {
    let mut out = MatF32::zeros(a.rows, q.n);
    fused_gemm_splitk_into(a, q, cfg, &mut SplitKScratch::new(), &mut out);
    out
}

/// [`fused_gemm_splitk`] writing into a caller-owned output and reusing
/// caller-owned slice partials — the allocation-free entry point the
/// decode path's per-worker scratch rides on. `out` is resized (not
/// accumulated) to `m × n`. Bit-identical to the allocating wrapper.
pub fn fused_gemm_splitk_into(a: &MatF32, q: &QuantizedLinear,
                              cfg: &HostKernelConfig,
                              scratch: &mut SplitKScratch,
                              out: &mut MatF32) {
    splitk_exec(a, WeightsRef::Flat(q), cfg, scratch, out);
}

/// The executor proper, generic over the weight storage (flat or
/// prepacked) — [`super::host_gemm_packed_into`] routes here too.
pub(crate) fn splitk_exec(a: &MatF32, wr: WeightsRef<'_>,
                          cfg: &HostKernelConfig,
                          scratch: &mut SplitKScratch,
                          out: &mut MatF32) {
    let q = wr.q();
    cfg.check_shapes(a, q);
    let (m, n) = (a.rows, q.n);
    let kp_total = q.k / PACK_FACTOR;
    let split = (cfg.split_k() as usize).min(kp_total.max(1));
    let bn = (cfg.tiles.block_n as usize).max(1);
    let kp_chunk = ((cfg.tiles.block_k as usize) / PACK_FACTOR).max(1);

    super::reset_output(out, m, n);
    if m == 0 || n == 0 || kp_total == 0 {
        return;
    }

    // Column span of one accumulation pass inside a worker. In the
    // skinny (m <= 2) regime the partial row fits in L1, so the worker
    // hands the kernel the full row width in one call (the kernel
    // internally segments flat spans at 64 columns to keep its LUT
    // panel L1-resident); for taller m the accumulator window is tiled
    // to block_n so it stays cache-resident.
    let colw = if m <= 2 { n } else { bn.min(n) };

    // `split`-entry slice table — §5 per-call bookkeeping, not a math
    // buffer.
    let slice_bounds: Vec<(usize, usize)> = (0..split)
        .map(|s| (s * kp_total / split, (s + 1) * kp_total / split))
        .collect(); // lint: allow(alloc): see bookkeeping note above
    let workers = cfg.effective_threads().min(split).max(1);
    scratch.ensure_tile_scratches(workers);
    // Size/zero the reusable partials for this call's (split, m, n).
    let SplitKScratch { partials, tile, allocs, .. } = scratch;
    partials.truncate(split);
    for p in partials.iter_mut() {
        ensure_zeroed(p, m, n, allocs);
    }
    while partials.len() < split {
        partials.push(MatF32::zeros(m, n));
        *allocs += 1;
    }
    let partials: &mut [MatF32] = &mut partials[..split];

    // Assign contiguous, balanced slice ranges (and one micro-kernel
    // scratch each) to workers up front, so every reference handed to a
    // scoped thread is created out here.
    let mut assignments: Vec<(&mut [MatF32], &[(usize, usize)],
                              &mut TileScratch)> =
        Vec::with_capacity(workers);
    {
        let mut rest: &mut [MatF32] = &mut partials[..];
        let mut ts_rest: &mut [TileScratch] = &mut tile[..workers];
        let mut next = 0usize;
        for w in 0..workers {
            let count = (split - next) / (workers - w);
            let (mine, tail) = rest.split_at_mut(count);
            rest = tail;
            let (ts, ts_tail) = ts_rest.split_at_mut(1);
            ts_rest = ts_tail;
            assignments.push((mine, &slice_bounds[next..next + count],
                              &mut ts[0]));
            next += count;
        }
    }
    std::thread::scope(|scope| {
        for (mine, my_bounds, ts) in assignments {
            scope.spawn(move || {
                for (partial, &(kp0, kp1)) in mine.iter_mut().zip(my_bounds) {
                    if kp0 >= kp1 {
                        continue;
                    }
                    let mut c0 = 0;
                    while c0 < n {
                        let c1 = (c0 + colw).min(n);
                        kernel_tile(a, wr, 0, m, c0, c1, kp0, kp1, kp_chunk,
                                    ts, &mut partial.data[c0..], n);
                        c0 = c1;
                    }
                }
            });
        }
    });

    // Deterministic pairwise tree over the slice partials (fixed shape
    // per split_k — the reproducible stand-in for the GPU's unordered
    // atomic adds).
    let mut gap = 1;
    while gap < split {
        let mut i = 0;
        while i + gap < split {
            let (head, tail) = partials.split_at_mut(i + gap);
            let dst = &mut head[i].data;
            let src = &tail[0].data;
            for (d, &s) in dst.iter_mut().zip(src.iter()) {
                *d += s;
            }
            i += 2 * gap;
        }
        gap *= 2;
    }
    out.data.copy_from_slice(&partials[0].data);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::TileConfig;
    use crate::quant::{quantize_weight, w4a16_gemm_ref};
    use crate::util::Rng;

    fn case(m: usize, k: usize, n: usize, group: usize, seed: u64)
            -> (MatF32, QuantizedLinear) {
        let mut rng = Rng::seed_from(seed);
        let w = MatF32::new(k, n, rng.normal_vec(k * n, 0.1));
        let q = quantize_weight(&w, group);
        let a = MatF32::new(
            m, k, (0..m * k).map(|_| rng.uniform_f32(-1.0, 1.0)).collect());
        (a, q)
    }

    #[test]
    fn matches_naive_reference_all_splits() {
        let (a, q) = case(3, 192, 24, 32, 20);
        let want = w4a16_gemm_ref(&a, &q);
        for split in [1u32, 2, 3, 4, 7, 8, 16] {
            let cfg = HostKernelConfig::splitk(split);
            let got = fused_gemm_splitk(&a, &q, &cfg);
            assert!(got.max_abs_diff(&want) <= 1e-4, "split={split}");
        }
    }

    #[test]
    fn uneven_slices_k_not_divisible() {
        // k/8 = 9 packed rows over split 4 -> slices of 2/2/2/3 rows.
        let (a, q) = case(2, 72, 16, 24, 21);
        let want = w4a16_gemm_ref(&a, &q);
        let got = fused_gemm_splitk(&a, &q, &HostKernelConfig::splitk(4));
        assert!(got.max_abs_diff(&want) <= 1e-4);
    }

    #[test]
    fn thread_count_is_bit_invariant() {
        let (a, q) = case(1, 256, 64, 64, 22);
        let cfg = HostKernelConfig::splitk(8);
        let base = fused_gemm_splitk(&a, &q, &cfg.clone().with_threads(1));
        for threads in [2, 3, 5, 8] {
            let got =
                fused_gemm_splitk(&a, &q, &cfg.clone().with_threads(threads));
            assert_eq!(base.data, got.data, "threads={threads}");
        }
    }

    #[test]
    fn split_one_equals_dp_exactly() {
        // A single slice is the same sequential reduction DP runs.
        let (a, q) = case(4, 128, 32, 32, 23);
        let sk = fused_gemm_splitk(&a, &q, &HostKernelConfig::splitk(1));
        let dp = crate::kernels::fused_gemm_dp(&a, &q, &HostKernelConfig::dp());
        assert_eq!(sk.data, dp.data);
    }

    #[test]
    fn split_larger_than_k_rows_degrades_gracefully() {
        let (a, q) = case(2, 16, 8, 8, 24);
        // Only 2 packed rows; split 16 clamps to 2.
        let want = w4a16_gemm_ref(&a, &q);
        let got = fused_gemm_splitk(&a, &q, &HostKernelConfig::splitk(16));
        assert!(got.max_abs_diff(&want) <= 1e-4);
    }

    #[test]
    fn scratch_reuse_is_bit_identical() {
        // One scratch carried across calls — including shape and split
        // changes between calls — must reproduce the fresh-scratch
        // result bit for bit (the decode path reuses scratch per step).
        let mut scratch = SplitKScratch::new();
        for (seed, m, k, n, group, split) in [
            (40u64, 1usize, 256usize, 64usize, 64usize, 8u32),
            (41, 4, 128, 32, 32, 4),
            (42, 1, 256, 64, 64, 8),
            (43, 2, 64, 16, 16, 2),
        ] {
            let (a, q) = case(m, k, n, group, seed);
            let cfg = HostKernelConfig::splitk(split).with_threads(2);
            let fresh = fused_gemm_splitk(&a, &q, &cfg);
            let mut out = MatF32::zeros(0, 0);
            fused_gemm_splitk_into(&a, &q, &cfg, &mut scratch, &mut out);
            assert_eq!(fresh.data, out.data, "seed={seed}");
            assert_eq!((out.rows, out.cols), (m, n));
        }
    }

    #[test]
    fn wide_m_uses_tiled_accumulator() {
        let (a, q) = case(16, 128, 40, 64, 25);
        let tiles =
            TileConfig { block_m: 16, block_n: 8, block_k: 32, warps: 1, stages: 1 };
        let cfg = HostKernelConfig::splitk(4).with_tiles(tiles);
        let want = w4a16_gemm_ref(&a, &q);
        let got = fused_gemm_splitk(&a, &q, &cfg);
        assert!(got.max_abs_diff(&want) <= 1e-4);
    }
}
