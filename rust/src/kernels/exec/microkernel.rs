//! Register-blocked LUT micro-kernel — the successor of
//! [`fused_tile`](super::fused::fused_tile) (DESIGN.md §5).
//!
//! Three changes over the reference micro-kernel, each bit-neutral:
//!
//! * **Per-group dequant LUTs** (LUT-GEMM / FLUTE's trick): an int4
//!   weight can only take 16 values, so for every (quantization group,
//!   column) the kernel precomputes `lut[v] = (v - zero) * scale` — the
//!   *exact* expression the reference kernel evaluates per nibble — and
//!   the inner loop replaces shift/mask/convert/sub/mul with
//!   shift/mask/load. One LUT panel (`16 × span_width` floats, ≤ 4 KiB
//!   at the default `block_n`) is built per (group, column span) and
//!   stays L1-resident across the whole k sweep of that group.
//! * **Register blocking**: instead of streaming the output row through
//!   memory once per k step, an `MR × (2·8)` accumulator tile lives in
//!   registers for a whole `block_k`-bounded run — loaded from the
//!   output window once per run and stored once, with 8-wide portable
//!   lanes ([`F32x8`]: a `[f32; 8]` wrapper whose elementwise ops the
//!   compiler keeps vectorized). The `scalar-microkernel` cargo feature
//!   swaps in a plain scalar loop — same operations, same order, same
//!   bits — so the SIMD path can always be differentially tested
//!   against it (CI runs the full test suite under both).
//! * **Prepacked traversal** ([`PackedLinear`]): when the caller hands a
//!   tile-major prepacked copy of the weights, the k sweep reads one
//!   contiguous panel stream instead of striding by the full row pitch,
//!   and scale/zero streams arrive unpacked.
//!
//! **Determinism contract (unchanged):** for every output element the k
//! reduction runs in strictly ascending k order over `[8·kp0, 8·kp1)`
//! with the same `acc + (a · w)` operation chain as the reference
//! kernel. Column/row sub-blocking, lane width, run boundaries, and the
//! flat-vs-prepacked source never touch a given element's chain, so
//! every output bit matches `fused_tile` — property tests pin this
//! across the full ragged-shape grid.

use crate::quant::{MatF32, QuantizedLinear, PACK_FACTOR};

use super::layout::PackedLinear;

/// Column cap of one flat-layout segment: bounds the LUT panel at
/// `16 · 64` floats (4 KiB, L1-resident) regardless of the caller's
/// span width. Prepacked segments are bounded by their panel width
/// instead.
const FLAT_SEGMENT_COLS: usize = 64;

/// Register-tile height (rows per accumulator block).
#[cfg(not(feature = "scalar-microkernel"))]
const MR: usize = 4;
/// Register-tile width (columns per accumulator block: two 8-lane
/// vectors).
#[cfg(not(feature = "scalar-microkernel"))]
const LANE_SPAN: usize = 16;

/// Which storage the micro-kernel reads the weights from.
#[derive(Clone, Copy)]
pub(crate) enum WeightsRef<'a> {
    /// The canonical row-major `QuantizedLinear`.
    Flat(&'a QuantizedLinear),
    /// A tile-major prepacked copy (plus the source layer for shape
    /// metadata). Must satisfy `pack.matches(q)`.
    Packed {
        q: &'a QuantizedLinear,
        pack: &'a PackedLinear,
    },
}

impl<'a> WeightsRef<'a> {
    /// The underlying layer (shape/metadata source).
    pub(crate) fn q(&self) -> &'a QuantizedLinear {
        match self {
            WeightsRef::Flat(q) => q,
            WeightsRef::Packed { q, .. } => q,
        }
    }
}

/// Reusable per-worker micro-kernel scratch: the dequant LUT panel and
/// the row buffer the scalar tail consumes. Buffers grow to the widest
/// span seen and are then reused allocation-free (`allocs` counts the
/// growth events; [`super::SplitKScratch::alloc_events`] folds them into
/// the steady-state assertion the autotuner and decode loop rely on).
#[derive(Debug, Default)]
pub(crate) struct TileScratch {
    /// Dequant LUT panel, `16 · span` floats: entry `t·16 + v` is column
    /// `t`'s dequantized value for nibble `v` in the current group.
    lut: Vec<f32>,
    /// Dequantized row span for the scalar (non-register-tiled) path.
    wrow: Vec<f32>,
    /// Buffer growth events (see [`super::SplitKScratch::alloc_events`]).
    pub(crate) allocs: u64,
}

impl TileScratch {
    /// Grow the buffers to cover a `bw`-wide span (never shrinks — the
    /// decode loop alternates projection widths and must not thrash).
    fn ensure(&mut self, bw: usize) {
        if self.wrow.len() < bw {
            self.wrow.resize(bw, 0.0);
            self.lut.resize(bw * 16, 0.0);
            self.allocs += 1;
        }
    }
}

/// Where a LUT panel's scale/zero parameters come from.
#[derive(Clone, Copy)]
enum LutSrc<'a> {
    /// Flat layer + first column of the span (zeros unpacked on the
    /// fly with [`QuantizedLinear::zero_at`]).
    Flat(&'a QuantizedLinear, usize),
    /// Prepacked panel streams of width `w`; the span starts at column
    /// offset `j0` inside the panel.
    Panel {
        scales: &'a [f32],
        zeros: &'a [f32],
        w: usize,
        j0: usize,
    },
}

/// Build the 16-entry-per-column LUT for group `grp` over a `bw`-wide
/// span: `lut[t·16 + v] = (v - zero) * scale` — bit-identical to the
/// reference kernel's in-loop `(nibble - zero) * scale`.
fn build_lut(src: &LutSrc<'_>, grp: usize, bw: usize, lut: &mut [f32]) {
    match *src {
        LutSrc::Flat(q, s0) => {
            for t in 0..bw {
                let z = q.zero_at(grp, s0 + t) as f32;
                let s = q.scale_at(grp, s0 + t);
                for v in 0..16 {
                    lut[t * 16 + v] = (v as f32 - z) * s;
                }
            }
        }
        LutSrc::Panel { scales, zeros, w, j0 } => {
            for t in 0..bw {
                let z = zeros[grp * w + j0 + t];
                let s = scales[grp * w + j0 + t];
                for v in 0..16 {
                    lut[t * 16 + v] = (v as f32 - z) * s;
                }
            }
        }
    }
}

/// Packed-word row access for one column span, monomorphized per
/// storage layout so the inner loops carry no dispatch.
trait WordRows {
    /// The span's packed words of k row `kp` (length = span width).
    fn row(&self, kp: usize) -> &[i32];
}

/// Span `s0..s1` of the flat row-major `qweight`.
struct FlatRows<'a> {
    data: &'a [i32],
    n: usize,
    s0: usize,
    s1: usize,
}

impl WordRows for FlatRows<'_> {
    #[inline(always)]
    fn row(&self, kp: usize) -> &[i32] {
        &self.data[kp * self.n + self.s0..kp * self.n + self.s1]
    }
}

/// Columns `j0..j1` of one prepacked panel of width `w`.
struct PanelRows<'a> {
    words: &'a [i32],
    w: usize,
    j0: usize,
    j1: usize,
}

impl WordRows for PanelRows<'_> {
    #[inline(always)]
    fn row(&self, kp: usize) -> &[i32] {
        &self.words[kp * self.w + self.j0..kp * self.w + self.j1]
    }
}

/// Portable 8-lane f32 vector: a `[f32; 8]` whose elementwise ops stay
/// in one basic block so the optimizer lowers them to the target's
/// native SIMD. Lane ops are exactly the scalar ops applied per lane —
/// no horizontal operations, no FMA contraction — so results are
/// bit-identical to the scalar fallback.
#[cfg(not(feature = "scalar-microkernel"))]
#[derive(Clone, Copy)]
struct F32x8([f32; 8]);

#[cfg(not(feature = "scalar-microkernel"))]
impl F32x8 {
    #[inline(always)]
    fn load(s: &[f32]) -> Self {
        let mut v = [0.0f32; 8];
        v.copy_from_slice(&s[..8]);
        F32x8(v)
    }

    #[inline(always)]
    fn splat(x: f32) -> Self {
        F32x8([x; 8])
    }

    #[inline(always)]
    fn mul(self, o: Self) -> Self {
        let mut r = self.0;
        for t in 0..8 {
            r[t] *= o.0[t];
        }
        F32x8(r)
    }

    #[inline(always)]
    fn add(self, o: Self) -> Self {
        let mut r = self.0;
        for t in 0..8 {
            r[t] += o.0[t];
        }
        F32x8(r)
    }

    #[inline(always)]
    fn store(self, d: &mut [f32]) {
        d[..8].copy_from_slice(&self.0);
    }
}

/// One `MR_ROWS × 16` register tile over a `[kp0, kp1)` run: load the
/// accumulators from the output window once, sweep the run with the
/// LUT-gathered weight vectors, store once. Per element this is the
/// reference kernel's exact `acc += a·w` chain in ascending k — only
/// where the accumulator *lives* changed (registers vs a memory
/// round-trip per k step).
#[cfg(not(feature = "scalar-microkernel"))]
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn run_tile<const MR_ROWS: usize, W: WordRows>(
    a: &MatF32,
    rows: &W,
    kp0: usize,
    kp1: usize,
    r_abs: usize,
    win_r0: usize,
    j: usize,
    lut: &[f32],
    out: &mut [f32],
    out_stride: usize,
    col_off: usize,
    k: usize,
) {
    let mut acc = [[F32x8::splat(0.0); 2]; MR_ROWS];
    for r in 0..MR_ROWS {
        let o = (r_abs + r - win_r0) * out_stride + col_off + j;
        acc[r][0] = F32x8::load(&out[o..o + 8]);
        acc[r][1] = F32x8::load(&out[o + 8..o + 16]);
    }
    for kp in kp0..kp1 {
        let row = rows.row(kp);
        let words = &row[j..j + LANE_SPAN];
        for i in 0..PACK_FACTOR {
            let sh = (4 * i) as u32;
            // Gather this nibble's dequantized values from the LUT
            // (each column's 16 entries are one cache line).
            let mut lo = [0.0f32; 8];
            let mut hi = [0.0f32; 8];
            for t in 0..8 {
                lo[t] = lut[(j + t) * 16
                    + (((words[t] as u32) >> sh) & 0xF) as usize];
                hi[t] = lut[(j + 8 + t) * 16
                    + (((words[8 + t] as u32) >> sh) & 0xF) as usize];
            }
            let (wlo, whi) = (F32x8(lo), F32x8(hi));
            let kk = kp * PACK_FACTOR + i;
            for r in 0..MR_ROWS {
                let av = F32x8::splat(a.data[(r_abs + r) * k + kk]);
                acc[r][0] = acc[r][0].add(av.mul(wlo));
                acc[r][1] = acc[r][1].add(av.mul(whi));
            }
        }
    }
    for r in 0..MR_ROWS {
        let o = (r_abs + r - win_r0) * out_stride + col_off + j;
        acc[r][0].store(&mut out[o..o + 8]);
        acc[r][1].store(&mut out[o + 8..o + 16]);
    }
}

/// Scalar path: columns `j0..bw` of the span, all rows, reference loop
/// structure (dequantize a row span via the LUT, then rank-1 updates).
/// Serves as the ragged-width tail of the vector path and, under the
/// `scalar-microkernel` feature, as the whole kernel.
#[allow(clippy::too_many_arguments)]
fn scalar_run<W: WordRows>(
    a: &MatF32,
    rows: &W,
    kp0: usize,
    kp1: usize,
    r0: usize,
    r1: usize,
    j0: usize,
    bw: usize,
    lut: &[f32],
    wrow: &mut [f32],
    out: &mut [f32],
    out_stride: usize,
    col_off: usize,
    k: usize,
) {
    for kp in kp0..kp1 {
        let row = rows.row(kp);
        for i in 0..PACK_FACTOR {
            let sh = (4 * i) as u32;
            for t in j0..bw {
                wrow[t] =
                    lut[t * 16 + (((row[t] as u32) >> sh) & 0xF) as usize];
            }
            let kk = kp * PACK_FACTOR + i;
            for r in r0..r1 {
                let av = a.data[r * k + kk];
                let o = (r - r0) * out_stride + col_off;
                let orow = &mut out[o + j0..o + bw];
                for (oo, &ww) in orow.iter_mut().zip(&wrow[j0..bw]) {
                    *oo += av * ww;
                }
            }
        }
    }
}

/// One `[kp0, kp1)` run over the whole span: 16-column register tiles
/// (rows in blocks of [`MR`], monomorphized remainders) plus a scalar
/// tail for the ragged columns.
#[allow(clippy::too_many_arguments)]
fn run_span<W: WordRows>(
    a: &MatF32,
    rows: &W,
    kp0: usize,
    kp1: usize,
    r0: usize,
    r1: usize,
    bw: usize,
    lut: &[f32],
    wrow: &mut [f32],
    out: &mut [f32],
    out_stride: usize,
    col_off: usize,
    k: usize,
) {
    #[cfg(not(feature = "scalar-microkernel"))]
    let j0 = {
        let mut j = 0;
        while j + LANE_SPAN <= bw {
            let mut r = r0;
            while r + MR <= r1 {
                run_tile::<MR, W>(a, rows, kp0, kp1, r, r0, j, lut, out,
                                  out_stride, col_off, k);
                r += MR;
            }
            match r1 - r {
                1 => run_tile::<1, W>(a, rows, kp0, kp1, r, r0, j, lut, out,
                                      out_stride, col_off, k),
                2 => run_tile::<2, W>(a, rows, kp0, kp1, r, r0, j, lut, out,
                                      out_stride, col_off, k),
                3 => run_tile::<3, W>(a, rows, kp0, kp1, r, r0, j, lut, out,
                                      out_stride, col_off, k),
                _ => {}
            }
            j += LANE_SPAN;
        }
        j
    };
    #[cfg(feature = "scalar-microkernel")]
    let j0 = 0;
    if j0 < bw {
        scalar_run(a, rows, kp0, kp1, r0, r1, j0, bw, lut, wrow, out,
                   out_stride, col_off, k);
    }
}

/// Sweep one column segment `[s0, s1)` over `[kp0, kp1)`: build the LUT
/// panel whenever the quantization group changes, and hand each
/// `block_k`-bounded run to [`run_span`]. Run boundaries mirror the
/// reference kernel exactly (group end, cache block end, range end).
#[allow(clippy::too_many_arguments)]
fn segment_sweep<W: WordRows>(
    a: &MatF32,
    lut_src: &LutSrc<'_>,
    rows: &W,
    r0: usize,
    r1: usize,
    c0_win: usize,
    s0: usize,
    s1: usize,
    kp0: usize,
    kp1: usize,
    chunk: usize,
    gp: usize,
    k: usize,
    ts: &mut TileScratch,
    out: &mut [f32],
    out_stride: usize,
) {
    let bw = s1 - s0;
    ts.ensure(bw);
    let col_off = s0 - c0_win;
    let TileScratch { lut, wrow, .. } = ts;
    let lut = &mut lut[..bw * 16];
    let wrow = &mut wrow[..bw];

    let mut kp = kp0;
    let mut cur_grp = usize::MAX;
    while kp < kp1 {
        let grp = kp / gp;
        if grp != cur_grp {
            build_lut(lut_src, grp, bw, lut);
            cur_grp = grp;
        }
        let run_end = kp1.min((grp + 1) * gp).min(kp + chunk);
        run_span(a, rows, kp, run_end, r0, r1, bw, lut, wrow, out,
                 out_stride, col_off, k);
        kp = run_end;
    }
}

/// Accumulate the fused product into `out` — the drop-in successor of
/// [`fused_tile`](super::fused::fused_tile), same window contract
/// (`out` origin at `(r0, c0)`, accumulated not stored), same
/// per-element reduction order, bit-identical output.
#[allow(clippy::too_many_arguments)]
pub(crate) fn kernel_tile(
    a: &MatF32,
    wr: WeightsRef<'_>,
    r0: usize,
    r1: usize,
    c0: usize,
    c1: usize,
    kp0: usize,
    kp1: usize,
    kp_chunk: usize,
    ts: &mut TileScratch,
    out: &mut [f32],
    out_stride: usize,
) {
    if r0 >= r1 || c0 >= c1 || kp0 >= kp1 {
        return;
    }
    let q = wr.q();
    debug_assert!(r1 <= a.rows && c1 <= q.n);
    debug_assert!(kp1 <= q.k / PACK_FACTOR);
    debug_assert!(out_stride >= c1 - c0);
    let k = q.k;
    let gp = q.group_size / PACK_FACTOR;
    let chunk = kp_chunk.max(1);

    match wr {
        WeightsRef::Flat(q) => {
            // Cap flat segments at FLAT_SEGMENT_COLS so the LUT panel
            // stays L1-resident (16 × 64 floats = 4 KiB) no matter how
            // wide the caller's span is — the skinny-m SplitK path
            // sweeps full rows (`colw = n`). Column segmentation is
            // bit-neutral (it partitions elements, never an element's
            // k chain).
            let mut s0 = c0;
            while s0 < c1 {
                let s1 = (s0 + FLAT_SEGMENT_COLS).min(c1);
                let rows = FlatRows { data: &q.qweight.data, n: q.n, s0,
                                      s1 };
                let src = LutSrc::Flat(q, s0);
                segment_sweep(a, &src, &rows, r0, r1, c0, s0, s1, kp0, kp1,
                              chunk, gp, k, ts, out, out_stride);
                s0 = s1;
            }
        }
        WeightsRef::Packed { q: _, pack } => {
            debug_assert!(pack.matches(q));
            // Split the span at panel boundaries so each segment reads
            // one contiguous panel stream. Column segmentation cannot
            // affect any element's k chain, so this is bit-neutral.
            let bn = pack.block_n();
            let mut s0 = c0;
            while s0 < c1 {
                let p = s0 / bn;
                let pc0 = p * bn;
                let s1 = (pc0 + bn).min(c1);
                let w = pack.panel_width(p);
                let rows = PanelRows { words: pack.panel_words(p), w,
                                       j0: s0 - pc0, j1: s1 - pc0 };
                let src = LutSrc::Panel { scales: pack.panel_scales(p),
                                          zeros: pack.panel_zeros(p), w,
                                          j0: s0 - pc0 };
                segment_sweep(a, &src, &rows, r0, r1, c0, s0, s1, kp0, kp1,
                              chunk, gp, k, ts, out, out_stride);
                s0 = s1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::fused::fused_tile;
    use super::*;
    use crate::quant::quantize_weight;
    use crate::util::Rng;

    fn case(m: usize, k: usize, n: usize, group: usize, seed: u64)
            -> (MatF32, QuantizedLinear) {
        let mut rng = Rng::seed_from(seed);
        let w = MatF32::new(k, n, rng.normal_vec(k * n, 0.1));
        let q = quantize_weight(&w, group);
        let a = MatF32::new(
            m, k,
            (0..m * k)
                .map(|i| if i % 7 == 0 { 0.0 } else { rng.uniform_f32(-1.0, 1.0) })
                .collect());
        (a, q)
    }

    /// The acceptance bar at tile granularity: for a grid of ragged
    /// windows, the LUT kernel (flat and prepacked at several panel
    /// widths) must reproduce the reference `fused_tile` bit for bit.
    #[test]
    fn bit_identical_to_reference_tile_across_window_grid() {
        // Shapes divide into the windows unevenly on purpose.
        for (m, k, n, group, seed) in [
            (1usize, 64usize, 16usize, 32usize, 1u64),
            (3, 192, 40, 24, 2),
            (7, 72, 24, 24, 3),
            (16, 128, 72, 64, 4),
        ] {
            let (a, q) = case(m, k, n, group, seed);
            let kp_total = k / 8;
            let windows = [
                (0, m, 0, n, 0, kp_total, 4),
                (0, m, 0, n, 0, kp_total, 1),
                (0, 1, 0, n, 0, kp_total, 1000),
                (0, m, 3.min(n - 1), n, 0, kp_total, 3),
                (m / 2, m, 0, 17.min(n), kp_total / 3, kp_total, 2),
                (0, m, 5.min(n - 1), 21.min(n), 1.min(kp_total - 1),
                 kp_total, 5),
            ];
            for &(r0, r1, c0, c1, kp0, kp1, chunk) in &windows {
                if r0 >= r1 || c0 >= c1 || kp0 >= kp1 {
                    continue;
                }
                let bw = c1 - c0;
                let rows = r1 - r0;
                // Seed the windows with a nonzero pattern so the
                // accumulate (+=) contract is exercised too.
                let seed_out: Vec<f32> =
                    (0..rows * bw).map(|i| (i % 5) as f32 * 0.25).collect();
                let mut want = seed_out.clone();
                fused_tile(&a, &q, r0, r1, c0, c1, kp0, kp1, chunk,
                           &mut want, bw);
                let mut ts = TileScratch::default();
                let mut got = seed_out.clone();
                kernel_tile(&a, WeightsRef::Flat(&q), r0, r1, c0, c1, kp0,
                            kp1, chunk, &mut ts, &mut got, bw);
                assert_eq!(want, got,
                           "flat window r{r0}..{r1} c{c0}..{c1} kp{kp0}..{kp1}");
                for bn in [5usize, 8, 16, 64] {
                    let pack = PackedLinear::new(&q, bn);
                    let mut got = seed_out.clone();
                    kernel_tile(&a,
                                WeightsRef::Packed { q: &q, pack: &pack },
                                r0, r1, c0, c1, kp0, kp1, chunk, &mut ts,
                                &mut got, bw);
                    assert_eq!(want, got,
                               "packed bn={bn} window r{r0}..{r1} c{c0}..{c1}");
                }
            }
        }
    }

    #[test]
    fn k_ranges_compose_bitwise() {
        // Two disjoint packed-row ranges accumulated into one window ==
        // one full-range pass, exactly (same per-element order) — the
        // property the SplitK slice partials rely on.
        let (a, q) = case(2, 128, 24, 64, 10);
        let mut ts = TileScratch::default();
        let mut full = vec![0.0f32; 2 * 24];
        kernel_tile(&a, WeightsRef::Flat(&q), 0, 2, 0, 24, 0, 16, 3,
                    &mut ts, &mut full, 24);
        let mut split = vec![0.0f32; 2 * 24];
        kernel_tile(&a, WeightsRef::Flat(&q), 0, 2, 0, 24, 0, 5, 3,
                    &mut ts, &mut split, 24);
        kernel_tile(&a, WeightsRef::Flat(&q), 0, 2, 0, 24, 5, 16, 3,
                    &mut ts, &mut split, 24);
        assert_eq!(full, split);
    }

    #[test]
    fn scratch_reuse_across_spans_is_bit_stable() {
        // One TileScratch carried across different widths/groups must
        // not leak state between calls (the LUT is rebuilt per group,
        // the row buffer fully overwritten per span).
        let (a1, q1) = case(2, 64, 40, 16, 11);
        let (a2, q2) = case(1, 96, 8, 32, 12);
        let mut ts = TileScratch::default();
        for _ in 0..2 {
            let mut got = vec![0.0f32; 2 * 40];
            kernel_tile(&a1, WeightsRef::Flat(&q1), 0, 2, 0, 40, 0, 8, 2,
                        &mut ts, &mut got, 40);
            let mut want = vec![0.0f32; 2 * 40];
            fused_tile(&a1, &q1, 0, 2, 0, 40, 0, 8, 2, &mut want, 40);
            assert_eq!(want, got);
            let mut got = vec![0.0f32; 8];
            kernel_tile(&a2, WeightsRef::Flat(&q2), 0, 1, 0, 8, 0, 12, 4,
                        &mut ts, &mut got, 8);
            let mut want = vec![0.0f32; 8];
            fused_tile(&a2, &q2, 0, 1, 0, 8, 0, 12, 4, &mut want, 8);
            assert_eq!(want, got);
        }
        // Two growth events at most (one per distinct max width) — the
        // second pass reused both buffers.
        assert!(ts.allocs <= 2, "allocs {}", ts.allocs);
    }

    #[test]
    fn empty_windows_are_no_ops() {
        let (a, q) = case(2, 64, 16, 32, 13);
        let mut ts = TileScratch::default();
        let mut out = vec![7.0f32; 2 * 16];
        kernel_tile(&a, WeightsRef::Flat(&q), 0, 0, 0, 16, 0, 8, 1, &mut ts,
                    &mut out, 16);
        kernel_tile(&a, WeightsRef::Flat(&q), 0, 2, 4, 4, 0, 8, 1, &mut ts,
                    &mut out, 16);
        kernel_tile(&a, WeightsRef::Flat(&q), 0, 2, 0, 16, 3, 3, 1, &mut ts,
                    &mut out, 16);
        assert!(out.iter().all(|&v| v == 7.0));
    }
}
