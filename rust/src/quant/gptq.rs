//! Asymmetric per-group int4 quantizer (the GPTQ storage format's
//! round-to-nearest baseline), mirroring `compile/quant.py`.

use super::{pack_along_cols, pack_along_rows, MatF32, MatI32, QMAX};

/// Packed parameters of one W4A16 linear layer `[k, n]`.
#[derive(Debug, Clone)]
pub struct QuantizedLinear {
    /// Logical weight rows (k) and columns (n).
    pub k: usize,
    pub n: usize,
    /// Quantization group length along k.
    pub group_size: usize,
    /// Packed int4 weights `i32[k/8, n]`.
    pub qweight: MatI32,
    /// Per-(group, column) scales `f32[k/G, n]`.
    pub scales: MatF32,
    /// Packed per-(group, column) zero points `i32[k/G, n/8]`.
    pub qzeros: MatI32,
}

/// Quantize a dense `f32[k, n]` weight (row-major) to the W4 format.
///
/// Per (group, column): `scale = (max - min) / 15` (floored at 1e-8),
/// `zero = clamp(round(-min / scale), 0, 15)`,
/// `q = clamp(round(w / scale) + zero, 0, 15)`.
pub fn quantize_weight(w: &MatF32, group_size: usize) -> QuantizedLinear {
    let (k, n) = (w.rows, w.cols);
    assert_eq!(k % group_size, 0, "k must be a multiple of group_size");
    let groups = k / group_size;

    let mut scales = MatF32::zeros(groups, n);
    let mut zeros = vec![0u8; groups * n];
    let mut q = vec![0u8; k * n];

    for g in 0..groups {
        for c in 0..n {
            // Range extended to include 0 (matches compile/quant.py):
            // guarantees 0.0 is exactly representable and keeps constant
            // groups from degenerating to a ~0 scale.
            let mut wmin = 0.0f32;
            let mut wmax = 0.0f32;
            for r in 0..group_size {
                let v = w.at(g * group_size + r, c);
                wmin = wmin.min(v);
                wmax = wmax.max(v);
            }
            let scale = ((wmax - wmin) / QMAX as f32).max(1e-8);
            let zero = (-wmin / scale).round().clamp(0.0, QMAX as f32) as u8;
            *scales.at_mut(g, c) = scale;
            zeros[g * n + c] = zero;
            for r in 0..group_size {
                let row = g * group_size + r;
                let v = (w.at(row, c) / scale).round() + zero as f32;
                q[row * n + c] = v.clamp(0.0, QMAX as f32) as u8;
            }
        }
    }

    QuantizedLinear {
        k,
        n,
        group_size,
        qweight: pack_along_rows(&q, k, n),
        scales,
        qzeros: pack_along_cols(&zeros, groups, n),
    }
}

impl QuantizedLinear {
    /// Packed weight word holding k rows `8·kp .. 8·kp+7` of column `c`.
    #[inline]
    pub fn qword(&self, kp: usize, c: usize) -> i32 {
        self.qweight.data[kp * self.n + c]
    }

    /// Scale of quantization group `grp`, column `c`.
    #[inline]
    pub fn scale_at(&self, grp: usize, c: usize) -> f32 {
        self.scales.data[grp * self.n + c]
    }

    /// Zero point of quantization group `grp`, column `c`, unpacked from
    /// the n-packed `qzeros` word — the exact expression the fused
    /// kernels dequantize with (`w = (nibble - zero) * scale`).
    #[inline]
    pub fn zero_at(&self, grp: usize, c: usize) -> u32 {
        let np = self.n / super::PACK_FACTOR;
        let word = self.qzeros.data[grp * np + c / super::PACK_FACTOR] as u32;
        (word >> (4 * (c % super::PACK_FACTOR))) & 0xF
    }

    /// Byte sizes of the packed tensors — used by the simulator's traffic
    /// model and by the memory-savings accounting (W4 vs FP16).
    pub fn packed_bytes(&self) -> usize {
        self.qweight.data.len() * 4 + self.scales.data.len() * 4 + self.qzeros.data.len() * 4
    }

    /// Bytes the same weight would occupy as dense FP16.
    pub fn fp16_bytes(&self) -> usize {
        self.k * self.n * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{dequantize, unpack_along_rows};

    fn rand_mat(rows: usize, cols: usize, seed: u64) -> MatF32 {
        // Small deterministic LCG — keeps the substrate dependency-free.
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            ((state >> 11) as f64 / (1u64 << 53) as f64) as f32 * 2.0 - 1.0
        };
        let data = (0..rows * cols).map(|_| next()).collect();
        MatF32::new(rows, cols, data)
    }

    #[test]
    fn shapes() {
        let w = rand_mat(256, 64, 1);
        let q = quantize_weight(&w, 64);
        assert_eq!((q.qweight.rows, q.qweight.cols), (32, 64));
        assert_eq!((q.scales.rows, q.scales.cols), (4, 64));
        assert_eq!((q.qzeros.rows, q.qzeros.cols), (4, 8));
    }

    #[test]
    fn dequant_error_bound() {
        let w = rand_mat(128, 32, 2);
        let q = quantize_weight(&w, 32);
        let wd = dequantize(&q);
        for r in 0..128 {
            for c in 0..32 {
                let bound = q.scales.at(r / 32, c) * 0.5 + 1e-6;
                assert!(
                    (wd.at(r, c) - w.at(r, c)).abs() <= bound,
                    "({r},{c}) err {} > bound {bound}",
                    (wd.at(r, c) - w.at(r, c)).abs()
                );
            }
        }
    }

    #[test]
    fn extremes_hit_full_range() {
        let col: Vec<f32> = (0..64).map(|i| i as f32 / 63.0 * 2.0 - 1.0).collect();
        let data: Vec<f32> = col.iter().flat_map(|&v| [v; 8]).collect();
        let w = MatF32::new(64, 8, data);
        let q = quantize_weight(&w, 64);
        let vals = unpack_along_rows(&q.qweight);
        // fp rounding at the half-step boundary may cost one level.
        assert!(*vals.iter().min().unwrap() <= 1);
        assert!(*vals.iter().max().unwrap() >= 14);
    }

    #[test]
    fn memory_savings_is_about_4x() {
        let w = rand_mat(512, 512, 3);
        let q = quantize_weight(&w, 128);
        let ratio = q.fp16_bytes() as f64 / q.packed_bytes() as f64;
        assert!(ratio > 3.0 && ratio < 4.0, "ratio {ratio}");
    }

    #[test]
    #[should_panic(expected = "multiple of group_size")]
    fn rejects_bad_group() {
        quantize_weight(&MatF32::zeros(100, 8), 64);
    }

    #[test]
    fn accessors_match_unpacked_tensors() {
        let w = rand_mat(64, 24, 4);
        let q = quantize_weight(&w, 16);
        let nibbles = unpack_along_rows(&q.qweight);
        let zeros = crate::quant::unpack_along_cols(&q.qzeros);
        for kp in 0..q.k / 8 {
            for c in 0..q.n {
                let word = q.qword(kp, c) as u32;
                for i in 0..8 {
                    assert_eq!(((word >> (4 * i)) & 0xF) as u8,
                               nibbles[(kp * 8 + i) * q.n + c]);
                }
            }
        }
        for grp in 0..q.k / q.group_size {
            for c in 0..q.n {
                assert_eq!(q.scale_at(grp, c), q.scales.at(grp, c));
                assert_eq!(q.zero_at(grp, c), zeros[grp * q.n + c] as u32);
            }
        }
    }
}
