//! S7 — GPTQ-style W4 quantization substrate (Rust side).
//!
//! Bit-for-bit the same storage format as `python/compile/quant.py`:
//!
//! * `qweight`: `i32[K/8, N]` — 8 int4 nibbles packed along K; nibble `i`
//!   (bits `4i..4i+3`) of `qweight[r][n]` holds weight row `r*8 + i`.
//! * `scales`: `f32[K/G, N]` — per-(group, column) scale.
//! * `qzeros`: `i32[K/G, N/8]` — per-(group, column) zero points, packed
//!   along N.
//!
//! The Rust side needs this to (a) quantize weights for the GEMM
//! artifacts' runtime inputs, (b) compute CPU reference results that
//! cross-check what the PJRT executables return, and (c) feed the
//! simulator exact byte-traffic numbers.

mod gemm_ref;
mod gptq;
mod pack;

pub use gemm_ref::{dequantize, gemm_f32, w4a16_gemm_ref};
pub use gptq::{quantize_weight, QuantizedLinear};
pub use pack::{
    pack_along_cols, pack_along_rows, unpack_along_cols, unpack_along_rows,
};

/// int4 values per packed i32 word.
pub const PACK_FACTOR: usize = 8;
/// Unsigned 4-bit maximum.
pub const QMAX: u32 = 15;

/// A dense row-major matrix of `f32` — the minimal tensor type the
/// substrate needs (activations, scales, reference outputs).
#[derive(Debug, Clone, PartialEq)]
pub struct MatF32 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl MatF32 {
    /// Create a matrix from row-major data; panics if sizes disagree.
    pub fn new(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "MatF32 size mismatch");
        Self { rows, cols, data }
    }

    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Element accessor (row-major).
    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    /// Mutable element accessor (row-major).
    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    /// Max absolute elementwise difference against another matrix.
    pub fn max_abs_diff(&self, other: &MatF32) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// A dense row-major matrix of packed `i32` words.
#[derive(Debug, Clone, PartialEq)]
pub struct MatI32 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<i32>,
}

impl MatI32 {
    /// Create a matrix from row-major data; panics if sizes disagree.
    pub fn new(rows: usize, cols: usize, data: Vec<i32>) -> Self {
        assert_eq!(data.len(), rows * cols, "MatI32 size mismatch");
        Self { rows, cols, data }
    }

    /// Element accessor (row-major).
    #[inline]
    pub fn at(&self, r: usize, c: usize) -> i32 {
        self.data[r * self.cols + c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matf32_accessors() {
        let mut m = MatF32::zeros(2, 3);
        *m.at_mut(1, 2) = 5.0;
        assert_eq!(m.at(1, 2), 5.0);
        assert_eq!(m.at(0, 0), 0.0);
    }

    #[test]
    #[should_panic(expected = "size mismatch")]
    fn matf32_size_checked() {
        MatF32::new(2, 2, vec![0.0; 3]);
    }

    #[test]
    fn max_abs_diff() {
        let a = MatF32::new(1, 2, vec![1.0, 2.0]);
        let b = MatF32::new(1, 2, vec![1.5, 2.0]);
        assert_eq!(a.max_abs_diff(&b), 0.5);
    }
}
