//! int4 <-> packed-i32 conversions, mirroring `compile/quant.py` exactly.

use super::{MatI32, PACK_FACTOR, QMAX};

/// Pack uint4 values (rows are the packed axis) into i32.
///
/// `q` is a row-major `[k, n]` slice of values in `0..=15`; returns
/// `i32[k/8, n]`. Panics if `k % 8 != 0` or any value is out of range.
pub fn pack_along_rows(q: &[u8], k: usize, n: usize) -> MatI32 {
    assert_eq!(q.len(), k * n, "pack_along_rows: size mismatch");
    assert_eq!(k % PACK_FACTOR, 0, "k must be a multiple of 8");
    let kp = k / PACK_FACTOR;
    let mut out = vec![0i32; kp * n];
    for rp in 0..kp {
        for i in 0..PACK_FACTOR {
            let row = rp * PACK_FACTOR + i;
            for c in 0..n {
                let v = q[row * n + c] as u32;
                assert!(v <= QMAX, "value {v} out of int4 range");
                out[rp * n + c] |= (v << (4 * i)) as i32;
            }
        }
    }
    MatI32::new(kp, n, out)
}

/// Inverse of [`pack_along_rows`]: `i32[k/8, n]` -> `u8[k, n]`.
pub fn unpack_along_rows(packed: &MatI32) -> Vec<u8> {
    let (kp, n) = (packed.rows, packed.cols);
    let mut out = vec![0u8; kp * PACK_FACTOR * n];
    for rp in 0..kp {
        for c in 0..n {
            let word = packed.data[rp * n + c] as u32;
            for i in 0..PACK_FACTOR {
                out[(rp * PACK_FACTOR + i) * n + c] = ((word >> (4 * i)) & 0xF) as u8;
            }
        }
    }
    out
}

/// Pack uint4 values (cols are the packed axis) into i32.
///
/// `z` is a row-major `[g, n]` slice of values in `0..=15`; returns
/// `i32[g, n/8]`. Panics if `n % 8 != 0` or any value is out of range.
pub fn pack_along_cols(z: &[u8], g: usize, n: usize) -> MatI32 {
    assert_eq!(z.len(), g * n, "pack_along_cols: size mismatch");
    assert_eq!(n % PACK_FACTOR, 0, "n must be a multiple of 8");
    let np = n / PACK_FACTOR;
    let mut out = vec![0i32; g * np];
    for r in 0..g {
        for cp in 0..np {
            let mut word = 0u32;
            for i in 0..PACK_FACTOR {
                let v = z[r * n + cp * PACK_FACTOR + i] as u32;
                assert!(v <= QMAX, "value {v} out of int4 range");
                word |= v << (4 * i);
            }
            out[r * np + cp] = word as i32;
        }
    }
    MatI32::new(g, np, out)
}

/// Inverse of [`pack_along_cols`]: `i32[g, n/8]` -> `u8[g, n]`.
pub fn unpack_along_cols(packed: &MatI32) -> Vec<u8> {
    let (g, np) = (packed.rows, packed.cols);
    let n = np * PACK_FACTOR;
    let mut out = vec![0u8; g * n];
    for r in 0..g {
        for cp in 0..np {
            let word = packed.data[r * np + cp] as u32;
            for i in 0..PACK_FACTOR {
                out[r * n + cp * PACK_FACTOR + i] = ((word >> (4 * i)) & 0xF) as u8;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_rows() {
        let k = 16;
        let n = 5;
        let q: Vec<u8> = (0..k * n).map(|i| (i % 16) as u8).collect();
        let packed = pack_along_rows(&q, k, n);
        assert_eq!(packed.rows, 2);
        assert_eq!(packed.cols, 5);
        assert_eq!(unpack_along_rows(&packed), q);
    }

    #[test]
    fn roundtrip_cols() {
        let g = 3;
        let n = 16;
        let z: Vec<u8> = (0..g * n).map(|i| ((i * 7) % 16) as u8).collect();
        let packed = pack_along_cols(&z, g, n);
        assert_eq!(packed.cols, 2);
        assert_eq!(unpack_along_cols(&packed), z);
    }

    #[test]
    fn nibble_order_matches_python() {
        // Row r*8+i -> bits 4i..4i+3 (kernel unpacks with >> 4i & 0xF).
        let mut q = vec![0u8; 8];
        q[3] = 0xA;
        let packed = pack_along_rows(&q, 8, 1);
        assert_eq!((packed.data[0] as u32 >> 12) & 0xF, 0xA);
    }

    #[test]
    fn sign_bit_roundtrip() {
        // Nibble 7 = 15 sets the i32 sign bit; masked unpack must survive.
        let q = vec![15u8; 8];
        let packed = pack_along_rows(&q, 8, 1);
        assert!(packed.data[0] < 0);
        assert_eq!(unpack_along_rows(&packed), q);
    }

    #[test]
    #[should_panic(expected = "multiple of 8")]
    fn rejects_bad_k() {
        pack_along_rows(&[0u8; 7], 7, 1);
    }

    #[test]
    #[should_panic(expected = "out of int4 range")]
    fn rejects_out_of_range() {
        pack_along_rows(&[16u8; 8], 8, 1);
    }
}
