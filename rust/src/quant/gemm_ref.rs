//! CPU reference dequantization + GEMM — the Rust-side oracle that
//! cross-checks what the PJRT executables return (integration tests,
//! examples, and the serving engine's self-check mode).

use super::{unpack_along_cols, unpack_along_rows, MatF32, QuantizedLinear};

/// Dequantize a packed linear back to dense `f32[k, n]`:
/// `w[r][c] = (q[r][c] - z[r/G][c]) * s[r/G][c]`.
pub fn dequantize(q: &QuantizedLinear) -> MatF32 {
    let (k, n, g) = (q.k, q.n, q.group_size);
    let qv = unpack_along_rows(&q.qweight);
    let zv = unpack_along_cols(&q.qzeros);
    let mut out = MatF32::zeros(k, n);
    for r in 0..k {
        let grp = r / g;
        for c in 0..n {
            let z = zv[grp * n + c] as f32;
            let s = q.scales.at(grp, c);
            *out.at_mut(r, c) = (qv[r * n + c] as f32 - z) * s;
        }
    }
    out
}

/// Plain dense `f32` GEMM: `C[m,n] = A[m,k] @ B[k,n]` (f32 accumulate).
pub fn gemm_f32(a: &MatF32, b: &MatF32) -> MatF32 {
    assert_eq!(a.cols, b.rows, "gemm_f32: inner dims disagree");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = MatF32::zeros(m, n);
    for i in 0..m {
        for l in 0..k {
            let av = a.at(i, l);
            if av == 0.0 {
                continue;
            }
            let brow = &b.data[l * n..(l + 1) * n];
            let crow = &mut c.data[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
    c
}

/// Reference fused W4A16 GEMM: `C = A @ dequant(Q)`.
pub fn w4a16_gemm_ref(a: &MatF32, q: &QuantizedLinear) -> MatF32 {
    assert_eq!(a.cols, q.k, "activation k != weight k");
    gemm_f32(a, &dequantize(q))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quantize_weight;

    #[test]
    fn gemm_identity() {
        let mut eye = MatF32::zeros(3, 3);
        for i in 0..3 {
            *eye.at_mut(i, i) = 1.0;
        }
        let b = MatF32::new(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(gemm_f32(&eye, &b), b);
    }

    #[test]
    fn gemm_known_values() {
        let a = MatF32::new(2, 2, vec![1., 2., 3., 4.]);
        let b = MatF32::new(2, 2, vec![1., 1., 1., 1.]);
        let c = gemm_f32(&a, &b);
        assert_eq!(c.data, vec![3., 3., 7., 7.]);
    }

    #[test]
    #[should_panic(expected = "inner dims disagree")]
    fn gemm_checks_dims() {
        gemm_f32(&MatF32::zeros(2, 3), &MatF32::zeros(2, 2));
    }

    #[test]
    fn fused_ref_matches_manual() {
        let data: Vec<f32> = (0..64 * 8).map(|i| ((i * 37) % 100) as f32 / 50.0 - 1.0).collect();
        let w = MatF32::new(64, 8, data);
        let q = quantize_weight(&w, 32);
        let a = MatF32::new(2, 64, (0..128).map(|i| (i % 7) as f32 * 0.1).collect());
        let got = w4a16_gemm_ref(&a, &q);
        let want = gemm_f32(&a, &dequantize(&q));
        assert_eq!(got, want);
    }

    #[test]
    fn quantize_dequant_gemm_close_to_dense() {
        // End-to-end: the quantization error in C is bounded by
        // sum_k |a| * scale/2.
        let data: Vec<f32> = (0..128 * 16)
            .map(|i| (((i * 131) % 997) as f32 / 997.0 - 0.5) * 0.1)
            .collect();
        let w = MatF32::new(128, 16, data);
        let q = quantize_weight(&w, 64);
        let a = MatF32::new(1, 128, vec![0.05; 128]);
        let dense = gemm_f32(&a, &w);
        let fused = w4a16_gemm_ref(&a, &q);
        let max_scale = q.scales.data.iter().fold(0.0f32, |m, &s| m.max(s));
        let bound = 128.0 * 0.05 * max_scale * 0.5 + 1e-5;
        assert!(dense.max_abs_diff(&fused) <= bound);
    }
}
