//! S14 — paper table & figure regeneration harness.
//!
//! One function per experiment in DESIGN.md §5. Each returns structured
//! rows (and can render the paper's table layout) so the criterion
//! benches, the `paper-tables` example, and EXPERIMENTS.md all share one
//! source of truth.


use crate::gpusim::{simulate, DeviceConfig, NsightReport, SimResult};
use crate::kernels::{
    autotune_split_k, dp_launch, splitk_launch, AutotuneResult, GemmShape,
    TileConfig,
};

/// The paper's n = k sweep axis (Tables 1–6, Figures 3–8).
pub const NK_SWEEP: [u64; 6] = [512, 1024, 2048, 4096, 8192, 16384];

/// One row of a SplitK-vs-DP TFLOPS table.
#[derive(Debug, Clone)]
pub struct TflopsRow {
    pub n: u64,
    pub k: u64,
    pub splitk_tflops: f64,
    pub dp_tflops: f64,
    /// splitk / dp — the per-row speedup.
    pub speedup: f64,
    pub splitk_us: f64,
    pub dp_us: f64,
}

/// A full SplitK-vs-DP table (one of Tables 1–6 / Figures 3–8).
#[derive(Debug, Clone)]
pub struct TflopsTable {
    pub device: String,
    pub m: u64,
    pub split_k: u32,
    pub rows: Vec<TflopsRow>,
}

/// Paper-recommended splitting factor per device (§3.3: 4 on A100,
/// 8 on H100).
pub fn paper_split_k(dev: &DeviceConfig) -> u32 {
    if dev.name.contains("H100") {
        8
    } else {
        4
    }
}

/// Generate one SplitK-vs-DP TFLOPS table: `m` fixed, n = k swept.
pub fn tflops_table(dev: &DeviceConfig, m: u64) -> TflopsTable {
    let split_k = paper_split_k(dev);
    let sk_tiles = TileConfig::paper_splitk();
    let dp_tiles = TileConfig::paper_dp();
    let rows = NK_SWEEP
        .iter()
        .map(|&nk| {
            let shape = GemmShape::square(m, nk);
            let sk = simulate(dev, &splitk_launch(dev, &shape, &sk_tiles, split_k));
            let dp = simulate(dev, &dp_launch(dev, &shape, &dp_tiles));
            let flops = shape.useful_flops();
            let sk_tf = sk.tflops(flops);
            let dp_tf = dp.tflops(flops);
            TflopsRow {
                n: nk,
                k: nk,
                splitk_tflops: sk_tf,
                dp_tflops: dp_tf,
                speedup: sk_tf / dp_tf,
                splitk_us: sk.timing.kernel_s * 1e6,
                dp_us: dp.timing.kernel_s * 1e6,
            }
        })
        .collect();
    TflopsTable { device: dev.name.clone(), m, split_k, rows }
}

impl TflopsTable {
    /// Geometric-mean speedup over the sweep (the paper quotes averages).
    pub fn mean_speedup(&self) -> f64 {
        let log_sum: f64 = self.rows.iter().map(|r| r.speedup.ln()).sum();
        (log_sum / self.rows.len() as f64).exp()
    }

    /// Peak speedup over the sweep.
    pub fn peak_speedup(&self) -> f64 {
        self.rows.iter().map(|r| r.speedup).fold(0.0, f64::max)
    }

    /// Render in the paper's layout.
    pub fn render(&self) -> String {
        let mut s = format!(
            "SplitK vs Data Parallel TFLOPS — {} — M={} (split_k={})\n\
             {:>6} {:>6} {:>16} {:>22} {:>9}\n",
            self.device, self.m, self.split_k,
            "N", "K", "SplitK [TFLOPS]", "Data Parallel [TFLOPS]", "Speedup"
        );
        for r in &self.rows {
            s.push_str(&format!(
                "{:>6} {:>6} {:>16.2} {:>22.2} {:>8.2}x\n",
                r.n, r.k, r.splitk_tflops, r.dp_tflops, r.speedup
            ));
        }
        s.push_str(&format!(
            "mean speedup {:.2}x   peak {:.2}x\n",
            self.mean_speedup(), self.peak_speedup()
        ));
        s
    }
}

/// Figures 9/10: TFLOPS (at m=16) for each splitting factor across the
/// n = k sweep.
#[derive(Debug, Clone)]
pub struct SplitFactorSweep {
    pub device: String,
    pub m: u64,
    /// (split_k, per-nk TFLOPS aligned with `NK_SWEEP`).
    pub series: Vec<(u32, Vec<f64>)>,
}

/// Generate the Figure 9/10 split-factor comparison for a device.
pub fn split_factor_sweep(dev: &DeviceConfig, m: u64) -> SplitFactorSweep {
    let tiles = TileConfig::paper_splitk();
    let mut series = Vec::new();
    for &sk in &[2u32, 4, 8, 16] {
        let mut tf = Vec::new();
        for &nk in &NK_SWEEP {
            let shape = GemmShape::square(m, nk);
            if tiles.validate(shape.k, shape.group_size, sk as u64).is_err() {
                tf.push(f64::NAN);
                continue;
            }
            let sim = simulate(dev, &splitk_launch(dev, &shape, &tiles, sk));
            tf.push(sim.tflops(shape.useful_flops()));
        }
        series.push((sk, tf));
    }
    SplitFactorSweep { device: dev.name.clone(), m, series }
}

impl SplitFactorSweep {
    /// The split factor with the best average TFLOPS over the sweep
    /// (paper: 4 on A100, 8 on H100). Averaged over the n=k rows valid
    /// for *every* factor, so a factor can't win by skipping its worst
    /// (divisibility-infeasible) sizes.
    pub fn best_split_k(&self) -> u32 {
        let common: Vec<usize> = (0..NK_SWEEP.len())
            .filter(|&i| self.series.iter().all(|(_, tf)| !tf[i].is_nan()))
            .collect();
        self.series
            .iter()
            .max_by(|a, b| {
                let mean = |tf: &[f64]| {
                    common.iter().map(|&i| tf[i]).sum::<f64>()
                        / common.len().max(1) as f64
                };
                mean(&a.1).partial_cmp(&mean(&b.1)).unwrap()
            })
            .map(|(sk, _)| *sk)
            .unwrap()
    }

    /// Render as aligned columns (one line per n=k, one column per split).
    pub fn render(&self) -> String {
        let mut s = format!("SplitK factor comparison — {} — M={}\n{:>7}",
                            self.device, self.m, "N=K");
        for (sk, _) in &self.series {
            s.push_str(&format!(" {:>10}", format!("split={sk}")));
        }
        s.push('\n');
        for (i, &nk) in NK_SWEEP.iter().enumerate() {
            s.push_str(&format!("{nk:>7}"));
            for (_, tf) in &self.series {
                if tf[i].is_nan() {
                    s.push_str(&format!(" {:>10}", "-"));
                } else {
                    s.push_str(&format!(" {:>10.2}", tf[i]));
                }
            }
            s.push('\n');
        }
        s.push_str(&format!("best split_k = {}\n", self.best_split_k()));
        s
    }
}


/// Table 7/8 + Figures 11/12: the Nsight-style comparison at
/// m=16, n=k=4096 on the A100.
pub fn nsight_comparison(dev: &DeviceConfig) -> (SimResult, SimResult) {
    let shape = GemmShape::square(16, 4096);
    let sk = simulate(dev, &splitk_launch(dev, &shape,
                                          &TileConfig::paper_splitk(), 4));
    let dp = simulate(dev, &dp_launch(dev, &shape, &TileConfig::paper_dp()));
    (sk, dp)
}

/// Render Table 7 + Table 8 side by side.
pub fn render_nsight_table(sk: &NsightReport, dp: &NsightReport) -> String {
    let rows: Vec<(&str, String, String)> = vec![
        ("Latency", format!("{:.2}us", sk.latency_us), format!("{:.2}us", dp.latency_us)),
        ("Global Memory Throughput", format!("{:.0} GB/s", sk.gmem_throughput_gbs),
         format!("{:.0} GB/s", dp.gmem_throughput_gbs)),
        ("Grid Size", sk.grid.to_string(), dp.grid.to_string()),
        ("Registers", sk.registers.to_string(), dp.registers.to_string()),
        ("Shared Memory Usage", format!("{:.2}KB", sk.smem_usage_kb),
         format!("{:.2}KB", dp.smem_usage_kb)),
        ("Block Limit (Registers)", sk.block_limit_regs.to_string(),
         dp.block_limit_regs.to_string()),
        ("Block Limit (SMEM)", sk.block_limit_smem.to_string(),
         dp.block_limit_smem.to_string()),
        ("Achieved Occupancy", format!("{:.2}", sk.achieved_occupancy_pct),
         format!("{:.2}", dp.achieved_occupancy_pct)),
        ("SM Utilization", format!("{:.2}%", sk.sm_utilization_pct),
         format!("{:.2}%", dp.sm_utilization_pct)),
        ("Active Warps", format!("{:.2}", sk.active_warps), format!("{:.2}", dp.active_warps)),
        ("Eligible Warps", format!("{:.2}", sk.eligible_warps), format!("{:.2}", dp.eligible_warps)),
        ("Issued Warps", format!("{:.2}", sk.issued_warps), format!("{:.2}", dp.issued_warps)),
        ("Issued IPC Active", format!("{:.2}", sk.issued_ipc_active),
         format!("{:.2}", dp.issued_ipc_active)),
        ("Occupancy Limiter", format!("{:?}", sk.limiter), format!("{:?}", dp.limiter)),
    ];
    let mut s = format!("{:<26} {:>12} {:>14}\n", "Metrics", "SplitK", "Data Parallel");
    for (name, a, b) in rows {
        s.push_str(&format!("{name:<26} {a:>12} {b:>14}\n"));
    }
    s
}

/// Table 9: the device spec comparison.
pub fn render_device_table() -> String {
    let devs = DeviceConfig::paper_devices();
    let mut s = format!("{:<18}", "Feature");
    for d in &devs {
        s.push_str(&format!(" {:>24}", d.name.replace("NVIDIA ", "")));
    }
    s.push('\n');
    let row = |label: &str, f: &dyn Fn(&DeviceConfig) -> String| {
        let mut line = format!("{label:<18}");
        for d in &devs {
            line.push_str(&format!(" {:>24}", f(d)));
        }
        line.push('\n');
        line
    };
    s.push_str(&row("SMs", &|d| d.sms.to_string()));
    s.push_str(&row("FP16 Tensor Core", &|d| format!("{:.0} TFLOPS", d.fp16_tflops)));
    s.push_str(&row("Memory Bandwidth", &|d| format!("{:.1} TB/s", d.mem_bw_gbs / 1000.0)));
    s.push_str(&row("L2 Cache", &|d| format!("{:.0}MB", d.l2_mb)));
    s.push_str(&row("L1 Cache/SM", &|d| format!("{:.0}KB", d.l1_kb_per_sm)));
    s.push_str(&row("Clock", &|d| format!("{:.2} GHz", d.clock_ghz)));
    s
}

/// Extension (paper §4 future work): StreamK vs tuned SplitK vs DP over
/// the n = k sweep at m = 16 — one row per size with simulated µs.
pub fn streamk_comparison(dev: &DeviceConfig, m: u64) -> Vec<(u64, f64, f64, f64)> {
    use crate::kernels::streamk_launch;
    let tiles = TileConfig::paper_splitk();
    NK_SWEEP
        .iter()
        .map(|&nk| {
            let shape = GemmShape::square(m, nk);
            let dp = simulate(dev, &dp_launch(dev, &shape, &TileConfig::paper_dp()))
                .timing.kernel_s * 1e6;
            let sk = simulate(dev, &splitk_launch(dev, &shape, &tiles,
                                                  paper_split_k(dev)))
                .timing.kernel_s * 1e6;
            let st = simulate(dev, &streamk_launch(dev, &shape, &tiles))
                .timing.kernel_s * 1e6;
            (nk, dp, sk, st)
        })
        .collect()
}

/// Render the StreamK extension table.
pub fn render_streamk(dev: &DeviceConfig, m: u64) -> String {
    let mut s = format!(
        "StreamK extension (paper §4) — {} — M={}\n{:>7} {:>12} {:>12} {:>12}\n",
        dev.name, m, "N=K", "DP µs", "SplitK µs", "StreamK µs");
    for (nk, dp, sk, st) in streamk_comparison(dev, m) {
        s.push_str(&format!("{nk:>7} {dp:>12.1} {sk:>12.1} {st:>12.1}\n"));
    }
    s
}

/// §2.2 ablation: "SplitK improves as GPU SM count improves". Sweep a
/// synthetic device's SM count and report the SplitK/DP speedup at
/// m = 16, n = k = 4096 — the mechanism behind the paper's H100 story.
pub fn sm_scaling_ablation(m: u64, nk: u64) -> Vec<(u32, f64)> {
    let base = DeviceConfig::a100_40gb_pcie();
    let tiles = TileConfig::paper_splitk();
    let dp_tiles = TileConfig::paper_dp();
    [60u32, 80, 108, 132, 160, 200]
        .iter()
        .map(|&sms| {
            let dev = DeviceConfig { sms, name: format!("synthetic-{sms}sm"),
                                     ..base.clone() };
            let shape = GemmShape::square(m, nk);
            let sk = simulate(&dev, &splitk_launch(&dev, &shape, &tiles, 4));
            let dp = simulate(&dev, &dp_launch(&dev, &shape, &dp_tiles));
            (sms, dp.timing.kernel_s / sk.timing.kernel_s)
        })
        .collect()
}

/// Autotune sweep used by the `autotune` command and `autotune_splitk`
/// example. Errs when the shape is infeasible for every splitting
/// factor (propagated from [`autotune_split_k`] — no longer a panic).
pub fn autotune_all_devices(m: u64, nk: u64)
                            -> Result<Vec<AutotuneResult>, String> {
    DeviceConfig::paper_devices()
        .iter()
        .map(|d| autotune_split_k(d, &GemmShape::square(m, nk),
                                  &TileConfig::paper_splitk()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_has_all_sweep_rows() {
        let dev = DeviceConfig::a100_40gb_pcie();
        let t = tflops_table(&dev, 16);
        assert_eq!(t.rows.len(), NK_SWEEP.len());
        assert!(t.rows.iter().all(|r| r.splitk_tflops > 0.0));
    }

    #[test]
    fn m16_is_16x_m1() {
        // Same launch geometry -> identical latency -> TFLOPS scale with m.
        let dev = DeviceConfig::a100_40gb_pcie();
        let t1 = tflops_table(&dev, 1);
        let t16 = tflops_table(&dev, 16);
        for (r1, r16) in t1.rows.iter().zip(&t16.rows) {
            assert!((r16.splitk_tflops / r1.splitk_tflops - 16.0).abs() < 0.1);
        }
    }

    #[test]
    fn splitk_wins_at_large_sizes_everywhere() {
        for dev in DeviceConfig::paper_devices() {
            let t = tflops_table(&dev, 16);
            for r in t.rows.iter().filter(|r| r.n >= 2048) {
                assert!(r.speedup > 1.0,
                        "{} n={} speedup {}", dev.name, r.n, r.speedup);
            }
        }
    }

    #[test]
    fn h100_gains_exceed_a100_gains() {
        // Paper §2.2: the SplitK advantage grows with SM count.
        let a40 = tflops_table(&DeviceConfig::a100_40gb_pcie(), 16);
        let h = tflops_table(&DeviceConfig::h100_pcie(), 16);
        assert!(h.mean_speedup() > a40.mean_speedup(),
                "h100 {:.2} vs a100 {:.2}", h.mean_speedup(), a40.mean_speedup());
    }

    #[test]
    fn nsight_comparison_shape() {
        // Table 7's qualitative content: SplitK has 4x grid, fewer regs,
        // less smem, higher occupancy + utilization + bandwidth, lower
        // latency.
        let dev = DeviceConfig::a100_40gb_pcie();
        let (sk, dp) = nsight_comparison(&dev);
        let (skr, dpr) = (sk.report(), dp.report());
        assert_eq!(skr.grid, 4 * dpr.grid);
        assert!(skr.registers < dpr.registers);
        assert!(skr.achieved_occupancy_pct > 2.0 * dpr.achieved_occupancy_pct);
        assert!(skr.sm_utilization_pct > 1.5 * dpr.sm_utilization_pct);
        assert!(skr.gmem_throughput_gbs > 1.5 * dpr.gmem_throughput_gbs);
        assert!(skr.latency_us < dpr.latency_us);
    }

    #[test]
    fn split_factor_sweep_renders() {
        let dev = DeviceConfig::h100_pcie();
        let sweep = split_factor_sweep(&dev, 16);
        assert_eq!(sweep.series.len(), 4);
        let text = sweep.render();
        assert!(text.contains("split=8"));
    }

    #[test]
    fn streamk_extension_wins_at_scale() {
        // The §4 hypothesis: StreamK >= tuned SplitK at large sizes.
        let dev = DeviceConfig::h100_pcie();
        for (nk, dp, sk, st) in streamk_comparison(&dev, 16) {
            assert!(st < dp, "streamk must beat DP at nk={nk}");
            if nk >= 8192 {
                assert!(st < sk * 1.15,
                        "nk={nk}: streamk {st} vs splitk {sk}");
            }
        }
    }

    #[test]
    fn sm_scaling_speedup_grows_with_sm_count() {
        // §2.2: more SMs -> DP wave-quantizes more -> SplitK gains grow.
        let sweep = sm_scaling_ablation(16, 4096);
        let first = sweep.first().unwrap().1;
        let last = sweep.last().unwrap().1;
        assert!(last > first,
                "speedup should grow with SMs: {first:.2} -> {last:.2}");
    }

    #[test]
    fn device_table_renders() {
        let t = render_device_table();
        assert!(t.contains("A100 80GB SXM"));
        assert!(t.contains("132"));
    }
}
