//! Bench: simulator hot paths — occupancy calculation, one full
//! simulate() call, and the Table-7 Nsight comparison. The simulator
//! sits inside the autotuner's search loop, so its per-call cost matters.

use splitk_w4a16::gpusim::{simulate, DeviceConfig, Occupancy};
use splitk_w4a16::kernels::{dp_launch, splitk_launch, GemmShape, TileConfig};
use splitk_w4a16::tables::nsight_comparison;
use splitk_w4a16::util::Bench;

fn main() {
    let dev = DeviceConfig::a100_40gb_pcie();
    let shape = GemmShape::square(16, 4096);
    let tiles = TileConfig::paper_splitk();
    let launch = splitk_launch(&dev, &shape, &tiles, 4);
    let dp = dp_launch(&dev, &shape, &TileConfig::paper_dp());

    let mut bench = Bench::default();
    bench.run("occupancy_compute", || {
        std::hint::black_box(Occupancy::compute(&dev, &launch));
    });
    bench.run("build_splitk_launch", || {
        std::hint::black_box(splitk_launch(&dev, &shape, &tiles, 4));
    });
    bench.run("simulate_splitk", || {
        std::hint::black_box(simulate(&dev, &launch));
    });
    bench.run("simulate_dp", || {
        std::hint::black_box(simulate(&dev, &dp));
    });
    bench.run("nsight_comparison_table7", || {
        std::hint::black_box(nsight_comparison(&dev));
    });
    std::fs::create_dir_all("results").ok();
    bench.write_json("results/bench_gpusim.json").ok();
}
