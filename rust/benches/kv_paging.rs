//! Bench: contiguous vs paged vs paged+prefix-cache KV serving on a
//! shared-system-prompt trace (`BENCH_kv.json`) — the measurement for
//! the paged KV memory manager (DESIGN.md §7 "KV memory manager").
//!
//! The trace models the dominant production shape for prefix caching:
//! every request opens with the same 48-token system prompt (three
//! 16-position KV blocks) followed by a short unique suffix. Three
//! engine configurations serve the identical trace and generate the
//! identical token count:
//!
//! * **contig**: the contiguous-lane fallback (`--kv-block-len 0`) —
//!   the pre-paging layout, the bit-identity baseline;
//! * **paged**: 16-position blocks, prefix cache off — isolates the
//!   cost of block-table indirection;
//! * **paged+prefix**: blocks + the prompt-hash trie — requests after
//!   the first attach the cached system-prompt blocks and skip that
//!   prefill work entirely.
//!
//! Equal tokens ⇒ the wall-clock ratio *is* the tokens/sec ratio. The
//! `ttft` series measure admission-to-first-token for a single
//! shared-prefix request against a cold trie vs a warm one (max_new 1,
//! so the request's whole life *is* its TTFT). Fixed kernel plan
//! (SplitK-4) throughout, so the comparison isolates KV layout.
//!
//! ```sh
//! cargo bench --bench kv_paging [-- --smoke]
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use splitk_w4a16::coordinator::{
    GenerateRequest, KvLayout, SamplingParams, SlotEngine,
};
use splitk_w4a16::kernels::HostKernelConfig;
use splitk_w4a16::metrics::ServingMetrics;
use splitk_w4a16::model::{GemmPlan, HostModel};
use splitk_w4a16::runtime::ModelMeta;
use splitk_w4a16::util::{Bench, Rng};

/// System-prompt length: exactly three 16-position blocks, so the trie
/// caches the whole shared head.
const SYSTEM_LEN: usize = 48;
const SLOTS: usize = 4;
const PREFILL_CHUNK: usize = 8;

fn meta() -> ModelMeta {
    ModelMeta::synthetic(128, "splitk", vec![1, 2, 4, 8, 16], 0)
}

fn fixed_model() -> HostModel {
    HostModel::with_plan(
        &meta(),
        GemmPlan::fixed(HostKernelConfig::splitk(4).with_threads(0)))
        .expect("host model")
}

fn engine(layout: KvLayout) -> (SlotEngine, Arc<ServingMetrics>) {
    let metrics = Arc::new(ServingMetrics::new());
    let engine = SlotEngine::with_layout(
        fixed_model(), SLOTS, PREFILL_CHUNK, metrics.clone(), layout)
        .expect("slot engine");
    (engine, metrics)
}

fn greq(id: u64, prompt: Vec<i32>, max_new: usize) -> GenerateRequest {
    GenerateRequest {
        id,
        prompt,
        max_new_tokens: max_new,
        stop_token: None,
        sampling: SamplingParams::greedy(),
        accepted_at: Instant::now(),
        deadline: None,
        priority: 0,
        stream: None,
    }
}

/// The shared 48-token system prompt (seeded once, identical across
/// every request and every series).
fn system_prompt() -> Vec<i32> {
    let mut rng = Rng::seed_from(42);
    (0..SYSTEM_LEN).map(|_| rng.gen_range(0, 512) as i32).collect()
}

/// `n` requests: shared system prompt + a unique 4..12-token suffix,
/// 6 generated tokens each.
fn build_trace(n: usize) -> Vec<GenerateRequest> {
    let system = system_prompt();
    let mut rng = Rng::seed_from(9);
    (0..n)
        .map(|i| {
            let mut prompt = system.clone();
            let extra = rng.gen_range(4, 12) as usize;
            prompt.extend((0..extra)
                .map(|_| rng.gen_range(0, 512) as i32));
            greq(i as u64 + 1, prompt, 6)
        })
        .collect()
}

/// Serve the whole trace: admit into free lanes, step to drain.
/// Returns tokens generated.
fn run_trace_saturated(engine: &mut SlotEngine,
                       trace: &[GenerateRequest]) -> usize {
    engine.reset();
    let mut idx = 0;
    let mut tokens = 0;
    while idx < trace.len() || !engine.is_idle() {
        while idx < trace.len() && engine.free_slots() > 0 {
            engine.admit(trace[idx].clone()).expect("admit");
            idx += 1;
        }
        for r in engine.step().expect("step") {
            tokens += r.tokens.len();
        }
    }
    tokens
}

/// One admission-to-first-token probe: a single shared-prefix request
/// with max_new 1 — its completion time is its TTFT.
fn run_ttft(engine: &mut SlotEngine, id: u64) {
    let mut prompt = system_prompt();
    prompt.extend([7, 13, 19]);
    engine.admit(greq(id, prompt, 1)).expect("admit");
    loop {
        if !engine.step().expect("step").is_empty() {
            return;
        }
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let n_requests = if smoke { 6 } else { 16 };
    let trace = build_trace(n_requests);
    let total_budget: usize =
        trace.iter().map(|r| r.max_new_tokens).sum();
    println!("trace: {n_requests} requests, shared {SYSTEM_LEN}-token \
              system prompt, {total_budget} token budget");

    let mut bench = if smoke {
        Bench::new(Duration::from_millis(400), 3, 0)
    } else {
        Bench::new(Duration::from_millis(2500), 6, 1)
    };

    let series = [
        ("kv_contig_trace_s4", KvLayout::contiguous()),
        ("kv_paged_trace_s4", KvLayout::paged(16, 0, false)),
        ("kv_paged_prefix_trace_s4", KvLayout::paged(16, 0, true)),
    ];
    for (name, layout) in series {
        let (mut eng, metrics) = engine(layout);
        let mut got = 0;
        let r = bench.run(name, || {
            got = run_trace_saturated(&mut eng, &trace);
        });
        assert_eq!(got, total_budget, "{name} must serve the full trace");
        let tps = total_budget as f64 / (r.mean_ns / 1e9);
        let hits = metrics.prefix_hits();
        let saved = metrics.prefix_tokens_saved();
        println!("  {name:<26} {tps:>9.1} tok/s   prefix_hits={hits} \
                  saved={saved}");
        if name == "kv_paged_prefix_trace_s4" {
            assert!(hits > 0,
                    "the shared-prefix trace must hit the prefix cache");
        }
    }

    // TTFT: cold trie (flushed before every probe) vs warm trie
    // (populated once, hit by every probe).
    let (mut cold, _) = engine(KvLayout::paged(16, 0, true));
    let mut id = 1_000u64;
    let r = bench.run("kv_ttft_cold_s4", || {
        cold.flush_prefix_cache();
        id += 1;
        run_ttft(&mut cold, id);
    });
    let cold_us = r.mean_ns / 1e3;

    let (mut warm, warm_metrics) = engine(KvLayout::paged(16, 0, true));
    run_ttft(&mut warm, 999); // populate the trie outside the timer
    let r = bench.run("kv_ttft_prefix_s4", || {
        id += 1;
        run_ttft(&mut warm, id);
    });
    let warm_us = r.mean_ns / 1e3;
    assert!(warm_metrics.prefix_hits() > 0,
            "warm TTFT probes must hit the prefix cache");
    println!("  ttft: cold {cold_us:>8.1} us   prefix-hit \
              {warm_us:>8.1} us   ({:.2}x)", cold_us / warm_us);

    let out = if smoke { "BENCH_kv_smoke.json" } else { "BENCH_kv.json" };
    match bench.write_repo_root_json(out) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
