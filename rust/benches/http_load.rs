//! Bench: HTTP front-door streaming latency (DESIGN.md §11) — p50/p95
//! time-to-first-token, inter-token latency, and end-to-end latency
//! under seeded Poisson open-loop arrivals (`BENCH_http.json`).
//!
//! An in-process server (host backend, continuous scheduler) is driven
//! by client threads over real loopback sockets. The driver thread
//! sleeps exponential inter-arrival gaps and launches one streaming
//! `/v1/completions` client per request; each client timestamps every
//! SSE token frame as it arrives off the socket, so:
//!
//! * **TTFT** — request write → first token frame. With per-token
//!   streaming this is roughly one decode step plus queueing, far
//!   below the full completion time; the bench asserts that ordering,
//!   which is exactly what distinguishes real streaming from
//!   harvest-then-replay.
//! * **ITL** — gap between consecutive token frames of one stream.
//! * **e2e** — request write → connection close (or, on a keep-alive
//!   connection, → the `data: [DONE]` sentinel that ends the stream).
//!
//! Besides the open-loop rate series, a closed-loop `keepalive` series
//! drives the same streamed completions sequentially down ONE
//! persistent connection — measuring what connection reuse buys over
//! connect-per-request on the same stack.
//!
//! ```sh
//! cargo bench --bench http_load [-- --smoke]
//! ```

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use splitk_w4a16::config::ServeConfig;
use splitk_w4a16::coordinator::Coordinator;
use splitk_w4a16::http::{HttpConfig, HttpServer};
use splitk_w4a16::util::bench::BenchResult;
use splitk_w4a16::util::{Json, Rng};

fn server_config() -> ServeConfig {
    ServeConfig {
        backend: "host".into(),
        artifacts_dir: "/nonexistent-artifacts".into(),
        slots: 4,
        prefill_chunk: 8,
        batch_window_ms: 1,
        max_new_tokens: 32,
        max_seq: 128,
        warm_start: false,
        self_check: false,
        http_addr: "127.0.0.1:0".into(),
        http_conns: 256,
        ..Default::default()
    }
}

/// Latency observations from one streamed completion.
struct Sample {
    ttft_ns: f64,
    itl_ns: Vec<f64>,
    e2e_ns: f64,
}

/// Drive one streaming completion and timestamp its token frames.
fn run_client(addr: SocketAddr, prompt: &[i32], max_tokens: usize)
              -> Sample {
    let body = format!(
        "{{\"prompt\": {:?}, \"max_tokens\": {max_tokens}, \
         \"stream\": true}}", prompt);
    let mut s = TcpStream::connect(addr).expect("connect");
    let t0 = Instant::now();
    s.write_all(format!(
        "POST /v1/completions HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
        body.len(), body).as_bytes()).expect("send");
    let mut frame_times: Vec<Instant> = Vec::new();
    let mut seen = 0usize;
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    loop {
        let n = s.read(&mut chunk).expect("read");
        if n == 0 {
            break;
        }
        let now = Instant::now();
        buf.extend_from_slice(&chunk[..n]);
        // Timestamp each *new* token frame in the buffer. Frames that
        // land in one read share a timestamp (their gap really was ~0:
        // they were back-to-back on the wire).
        let text = String::from_utf8_lossy(&buf);
        let count = text.matches("data: {\"token\":").count();
        for _ in seen..count {
            frame_times.push(now);
        }
        seen = count;
    }
    let e2e_ns = t0.elapsed().as_nanos() as f64;
    assert!(!frame_times.is_empty(), "stream produced no token frames");
    let text = String::from_utf8_lossy(&buf);
    assert!(text.contains("data: [DONE]"), "stream must end cleanly");
    let ttft_ns = frame_times[0].duration_since(t0).as_nanos() as f64;
    let itl_ns = frame_times
        .windows(2)
        .map(|w| w[1].duration_since(w[0]).as_nanos() as f64)
        .collect();
    Sample { ttft_ns, itl_ns, e2e_ns }
}

/// Drive `n` sequential streaming completions down ONE keep-alive
/// connection. Each stream is delimited by the `data: [DONE]` sentinel
/// rather than EOF, so e2e here is request write → sentinel.
fn run_keepalive_client(addr: SocketAddr, n: usize, seed: u64,
                        max_tokens: usize) -> Vec<Sample> {
    const SENTINEL: &str = "data: [DONE]\n\n";
    let mut rng = Rng::seed_from(seed);
    let mut s = TcpStream::connect(addr).expect("connect");
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    let mut samples = Vec::with_capacity(n);
    for i in 0..n {
        let plen = 2 + (i % 6);
        let prompt: Vec<i32> =
            (0..plen).map(|_| rng.gen_range(0, 512) as i32).collect();
        let body = format!(
            "{{\"prompt\": {:?}, \"max_tokens\": {max_tokens}, \
             \"stream\": true}}", prompt);
        let t0 = Instant::now();
        s.write_all(format!(
            "POST /v1/completions HTTP/1.1\r\nContent-Length: {}\r\n\
             Connection: keep-alive\r\n\r\n{}",
            body.len(), body).as_bytes()).expect("send");
        let mut frame_times: Vec<Instant> = Vec::new();
        let mut seen = 0usize;
        let stream_end = loop {
            let text = String::from_utf8_lossy(&buf);
            if let Some(p) = text.find(SENTINEL) {
                break p + SENTINEL.len();
            }
            let got = s.read(&mut chunk).expect("read");
            assert!(got > 0, "server closed a keep-alive stream early");
            let now = Instant::now();
            buf.extend_from_slice(&chunk[..got]);
            let count = String::from_utf8_lossy(&buf)
                .matches("data: {\"token\":")
                .count();
            for _ in seen..count {
                frame_times.push(now);
            }
            seen = count;
        };
        let e2e_ns = t0.elapsed().as_nanos() as f64;
        buf.drain(..stream_end);
        assert!(!frame_times.is_empty(), "stream produced no token frames");
        let ttft_ns = frame_times[0].duration_since(t0).as_nanos() as f64;
        let itl_ns = frame_times
            .windows(2)
            .map(|w| w[1].duration_since(w[0]).as_nanos() as f64)
            .collect();
        samples.push(Sample { ttft_ns, itl_ns, e2e_ns });
    }
    samples
}

/// Aggregate raw nanosecond samples into the repo's standard record.
fn aggregate(name: &str, mut ns: Vec<f64>) -> BenchResult {
    ns.sort_by(|a, b| a.partial_cmp(b).expect("finite sample"));
    let n = ns.len();
    BenchResult {
        name: name.to_string(),
        samples: n,
        mean_ns: ns.iter().sum::<f64>() / n as f64,
        p50_ns: ns[n / 2],
        p95_ns: ns[(n * 95 / 100).min(n - 1)],
        min_ns: ns[0],
        max_ns: ns[n - 1],
    }
}

/// One open-loop series: `n` requests, exponential gaps with the given
/// mean. Returns (ttft, itl, e2e) sample vectors.
fn run_series(addr: SocketAddr, n: usize, mean_gap_ms: f64, seed: u64,
              max_tokens: usize) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    let mut rng = Rng::seed_from(seed);
    let mut clients = Vec::with_capacity(n);
    for i in 0..n {
        let gap_ms = -rng.next_f64().max(1e-9).ln() * mean_gap_ms;
        thread::sleep(Duration::from_micros((gap_ms * 1e3) as u64));
        let plen = 2 + (i % 6);
        let prompt: Vec<i32> =
            (0..plen).map(|_| rng.gen_range(0, 512) as i32).collect();
        clients.push(thread::spawn(move || {
            run_client(addr, &prompt, max_tokens)
        }));
    }
    let mut ttft = Vec::new();
    let mut itl = Vec::new();
    let mut e2e = Vec::new();
    for c in clients {
        let s = c.join().expect("client thread");
        ttft.push(s.ttft_ns);
        itl.extend(s.itl_ns);
        e2e.push(s.e2e_ns);
    }
    (ttft, itl, e2e)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    // (offered rate label, mean inter-arrival gap ms, requests)
    let series: &[(&str, f64, usize)] = if smoke {
        &[("r100", 10.0, 8)]
    } else {
        &[("r25", 40.0, 48), ("r100", 10.0, 48), ("r400", 2.5, 48)]
    };
    let max_tokens = if smoke { 8 } else { 16 };

    let cfg = server_config();
    let coord = Arc::new(Coordinator::start(&cfg).expect("coordinator"));
    let server = HttpServer::start(Arc::clone(&coord),
                                   &HttpConfig::from_serve(&cfg))
        .expect("http server");
    let addr = server.addr();
    println!("http front door on {addr} ({} lane(s), {} max conns)",
             cfg.slots, cfg.http_conns);

    let mut results: Vec<BenchResult> = Vec::new();
    for (i, &(label, gap_ms, n)) in series.iter().enumerate() {
        println!("series {label}: {n} streamed completions, \
                  exponential gaps (mean {gap_ms} ms, seed {})", 11 + i);
        let (ttft, itl, e2e) =
            run_series(addr, n, gap_ms, 11 + i as u64, max_tokens);
        let ttft = aggregate(&format!("http_ttft_{label}"), ttft);
        let itl = aggregate(&format!("http_itl_{label}"), itl);
        let e2e = aggregate(&format!("http_e2e_{label}"), e2e);
        for r in [&ttft, &itl, &e2e] {
            println!("{}", r.line());
        }
        assert!(
            ttft.p50_ns < e2e.p50_ns,
            "TTFT must beat end-to-end — streaming is per-token, \
             not harvest-then-replay");
        results.extend([ttft, itl, e2e]);
    }

    // Closed-loop keep-alive series: one persistent connection serving
    // every request back to back, streams delimited by `data: [DONE]`.
    let ka_n = if smoke { 8 } else { 48 };
    println!("series keepalive_r100: {ka_n} streamed completions on one \
              keep-alive connection (seed 21)");
    let samples = run_keepalive_client(addr, ka_n, 21, max_tokens);
    let (mut ttft, mut itl, mut e2e) = (Vec::new(), Vec::new(), Vec::new());
    for s in samples {
        ttft.push(s.ttft_ns);
        itl.extend(s.itl_ns);
        e2e.push(s.e2e_ns);
    }
    let ttft = aggregate("http_ttft_keepalive_r100", ttft);
    let itl = aggregate("http_itl_keepalive_r100", itl);
    let e2e = aggregate("http_e2e_keepalive_r100", e2e);
    for r in [&ttft, &itl, &e2e] {
        println!("{}", r.line());
    }
    assert!(
        ttft.p50_ns < e2e.p50_ns,
        "TTFT must beat end-to-end on a reused connection too");
    results.extend([ttft, itl, e2e]);

    server.stop();
    match Arc::try_unwrap(coord) {
        Ok(c) => c.shutdown().expect("clean shutdown"),
        Err(_) => panic!("coordinator still shared after server stop"),
    }

    let out = if smoke { "BENCH_http_smoke.json" }
              else { "BENCH_http.json" };
    let arr = Json::Arr(results.iter().map(|r| r.to_json()).collect());
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap_or_else(|| std::path::Path::new("."));
    let path = root.join(out);
    match std::fs::write(&path, arr.to_string()) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
