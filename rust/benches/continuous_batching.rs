//! Bench: static batch-to-completion vs continuous slot-refill serving
//! on the host model, under a seeded Poisson-ish arrival trace — the
//! serving-side acceptance measurement for ISSUE 5 (`BENCH_serving.json`).
//!
//! The trace assigns each request an arrival *step* (exponential gaps)
//! and an exponential-ish generation budget, so request lifetimes are
//! staggered the way real traffic staggers them. Both schedulers serve
//! the identical trace and generate the identical token count:
//!
//! * **static**: FIFO groups of up to `slots` requests, each batch run
//!   to completion ([`Engine::run_batch`]) — the batch drains at its
//!   slowest member, so finished slots ride along as dead rows;
//! * **continuous**: a [`SlotEngine`] pool of `slots` lanes — finished
//!   requests free their lane for immediate refill and prompts enter
//!   via chunked prefill.
//!
//! Equal tokens ⇒ the wall-clock ratio *is* the tokens/sec ratio; the
//! per-series tok/s derived from the measured mean is printed and both
//! series land in the JSON. Both engines run the same fixed kernel plan
//! (SplitK-4, auto threads) so the comparison isolates scheduling — not
//! autotune luck — and the smoke mode needs no warm sweeps.
//!
//! ```sh
//! cargo bench --bench continuous_batching [-- --smoke]
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use splitk_w4a16::coordinator::{
    Batch, Engine, GenerateRequest, HostModelBackend, SamplingParams,
    SlotEngine,
};
use splitk_w4a16::kernels::HostKernelConfig;
use splitk_w4a16::metrics::ServingMetrics;
use splitk_w4a16::model::{GemmPlan, HostModel};
use splitk_w4a16::runtime::ModelMeta;
use splitk_w4a16::util::{Bench, Rng};

fn meta() -> ModelMeta {
    ModelMeta::synthetic(128, "splitk", vec![1, 2, 4, 8, 16], 0)
}

fn fixed_model() -> HostModel {
    HostModel::with_plan(
        &meta(),
        GemmPlan::fixed(HostKernelConfig::splitk(4).with_threads(0)))
        .expect("host model")
}

/// One trace entry: the virtual step the request arrives at, plus the
/// request itself.
type Trace = Vec<(usize, GenerateRequest)>;

/// Seeded Poisson-ish trace: exponential inter-arrival gaps (mean ~2
/// steps) and exponential-ish generation budgets (mean ~6, max 24), so
/// lanes free up at staggered times — the regime slot refill exists for.
fn build_trace(n: usize, seed: u64) -> Trace {
    let mut rng = Rng::seed_from(seed);
    let mut arrival = 0usize;
    (0..n)
        .map(|i| {
            arrival += (-rng.next_f64().max(1e-9).ln() * 2.0) as usize;
            let plen = rng.gen_range(2, 10) as usize;
            let prompt: Vec<i32> =
                (0..plen).map(|_| rng.gen_range(0, 512) as i32).collect();
            let max_new =
                1 + ((-rng.next_f64().max(1e-9).ln() * 6.0) as usize).min(23);
            let req = GenerateRequest {
                id: i as u64 + 1,
                prompt,
                max_new_tokens: max_new,
                stop_token: None,
                sampling: SamplingParams::greedy(),
                accepted_at: Instant::now(),
                deadline: None,
                priority: 0,
                stream: None,
            };
            (arrival, req)
        })
        .collect()
}

/// Smallest serving bucket covering `n` (the batcher's policy).
fn bucket_for(n: usize) -> usize {
    [1usize, 2, 4, 8, 16]
        .into_iter()
        .find(|&b| n <= b)
        .unwrap_or(16)
}

/// Continuous run: admit arrived requests into free lanes each step,
/// jump the virtual clock over idle gaps. Returns tokens generated.
fn run_continuous(engine: &mut SlotEngine, trace: &Trace) -> usize {
    engine.reset();
    let mut idx = 0;
    let mut clock = 0usize;
    let mut tokens = 0;
    while idx < trace.len() || !engine.is_idle() {
        while idx < trace.len() && trace[idx].0 <= clock
            && engine.free_slots() > 0
        {
            engine.admit(trace[idx].1.clone()).expect("admit");
            idx += 1;
        }
        if engine.is_idle() {
            // Nothing in flight: fast-forward to the next arrival.
            clock = clock.max(trace[idx].0);
            continue;
        }
        for r in engine.step().expect("step") {
            tokens += r.tokens.len();
        }
        clock += 1;
    }
    tokens
}

/// Static run: FIFO groups of up to `slots`, each batch run to
/// completion. Arrival times don't gate anything here — a static
/// engine has nothing to do until a full group is queued anyway, and
/// the measurement is pure compute time — so only the arrival *order*
/// (shared with the continuous run) shapes the batches. Returns tokens
/// generated.
fn run_static(engine: &mut Engine, trace: &Trace, slots: usize) -> usize {
    let mut idx = 0;
    let mut tokens = 0;
    while idx < trace.len() {
        let take = slots.min(trace.len() - idx);
        let requests: Vec<GenerateRequest> =
            trace[idx..idx + take].iter().map(|(_, r)| r.clone()).collect();
        idx += take;
        let out = engine
            .run_batch(Batch { requests, bucket: bucket_for(take) })
            .expect("run_batch");
        tokens += out.iter().map(|r| r.tokens.len()).sum::<usize>();
    }
    tokens
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let slot_counts: &[usize] = if smoke { &[4] } else { &[4, 8, 16] };
    let n_requests = if smoke { 10 } else { 32 };
    let prefill_chunk = 8;
    let trace = build_trace(n_requests, 7);
    let total_budget: usize =
        trace.iter().map(|(_, r)| r.max_new_tokens).sum();
    println!("trace: {n_requests} requests, {total_budget} token budget, \
              Poisson-ish arrivals (seed 7)");

    let mut bench = if smoke {
        Bench::new(Duration::from_millis(400), 3, 0)
    } else {
        Bench::new(Duration::from_millis(2500), 6, 1)
    };

    for &slots in slot_counts {
        let mut stat = Engine::new(
            Box::new(HostModelBackend::new(fixed_model())),
            Arc::new(ServingMetrics::new()));
        let mut want = 0;
        let r = bench.run(&format!("static_s{slots}"), || {
            want = run_static(&mut stat, &trace, slots);
        });
        assert_eq!(want, total_budget, "static must serve the full trace");
        let static_tps = total_budget as f64 / (r.mean_ns / 1e9);

        let mut cont = SlotEngine::new(fixed_model(), slots, prefill_chunk,
                                       Arc::new(ServingMetrics::new()))
            .expect("slot engine");
        let mut got = 0;
        let r = bench.run(&format!("continuous_s{slots}"), || {
            got = run_continuous(&mut cont, &trace);
        });
        assert_eq!(got, total_budget,
                   "continuous must serve the full trace");
        let cont_tps = total_budget as f64 / (r.mean_ns / 1e9);
        println!("  m={slots:>2}: static {static_tps:>8.1} tok/s   \
                  continuous {cont_tps:>8.1} tok/s   ({:.2}x)",
                 cont_tps / static_tps);
    }

    let out = if smoke { "BENCH_serving_smoke.json" }
              else { "BENCH_serving.json" };
    match bench.write_repo_root_json(out) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
