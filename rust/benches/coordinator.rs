//! Bench: L3 coordinator hot paths *without* PJRT — batcher push/poll
//! cycles, metrics recording, JSON/manifest parsing — plus, when the
//! artifacts are present, the end-to-end serving loop (decode step rate
//! and request turnaround through the real engine).

use std::path::PathBuf;
use std::time::{Duration, Instant};

use splitk_w4a16::config::ServeConfig;
use splitk_w4a16::coordinator::{Coordinator, DynamicBatcher,
                                GenerateRequest, SamplingParams};
use splitk_w4a16::metrics::ServingMetrics;
use splitk_w4a16::runtime::Manifest;
use splitk_w4a16::util::{Bench, Json};

fn req(id: u64, at: Instant) -> GenerateRequest {
    GenerateRequest {
        id,
        prompt: vec![1, 2, 3],
        max_new_tokens: 4,
        stop_token: None,
        sampling: SamplingParams::greedy(),
        accepted_at: at,
        deadline: None,
        priority: 0,
        stream: None,
    }
}

fn main() {
    let mut bench = Bench::default();

    // Batcher: full push->poll cycle for a 16-burst (the hot path that
    // sits in front of every decode step).
    bench.run("batcher_push_poll_16", || {
        let mut b = DynamicBatcher::new(vec![1, 2, 4, 8, 16],
                                        Duration::ZERO, 1024);
        let t0 = Instant::now();
        for i in 0..16 {
            b.push(req(i, t0)).unwrap();
        }
        while b.poll(t0).is_some() {}
    });

    // Metrics: request + step recording (engine-loop frequency).
    let metrics = ServingMetrics::new();
    bench.run("metrics_record_request", || {
        metrics.record_request(12.5, 8, 0.5);
    });
    bench.run("metrics_record_step", || {
        metrics.record_step(850.0, 16);
    });

    // Manifest parsing (startup path, also a JSON-parser macro-bench).
    let dir = PathBuf::from("artifacts");
    if dir.join("manifest.json").exists() {
        let text = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
        bench.run("json_parse_manifest", || {
            std::hint::black_box(Json::parse(&text).unwrap());
        });
        bench.run("manifest_load_validate", || {
            std::hint::black_box(Manifest::load(&dir).unwrap());
        });

        // End-to-end: one batched request through the live engine.
        let cfg = ServeConfig {
            artifacts_dir: dir,
            batch_window_ms: 1,
            max_new_tokens: 8,
            ..Default::default()
        };
        println!("starting live coordinator for e2e bench...");
        let coord = Coordinator::start(&cfg).expect("coordinator");
        let mut e2e = Bench::new(Duration::from_secs(20), 12, 1);
        e2e.run("e2e_request_b1_4tok", || {
            coord
                .submit(vec![5, 9, 13], 4, None)
                .unwrap()
                .wait()
                .unwrap();
        });
        e2e.run("e2e_burst16_2tok", || {
            let pending: Vec<_> = (0..16)
                .map(|i| coord.submit(vec![i + 1, 2], 2, None).unwrap())
                .collect();
            for p in pending {
                p.wait().unwrap();
            }
        });
        println!("{}", coord.metrics().summary());
        coord.shutdown().unwrap();
        std::fs::create_dir_all("results").ok();
        e2e.write_json("results/bench_coordinator_e2e.json").ok();
    } else {
        eprintln!("artifacts/ missing: skipping manifest + e2e benches");
    }
    std::fs::create_dir_all("results").ok();
    bench.write_json("results/bench_coordinator.json").ok();
}
