//! Bench: micro-kernel generations head to head — the measurement that
//! motivated the register-blocked rewrite (EXPERIMENTS.md).
//!
//! Three series per `(m, n = k)` cell, same tile geometry and thread
//! budget so only the kernel generation differs:
//!
//! * `legacy_dp_*`        — the pre-LUT reference executor
//!                          (`fused_gemm_legacy`: per-nibble
//!                          shift/mask/convert/sub/mul, output row
//!                          streamed through memory every k step);
//! * `fused_lut_dp_*`     — the register-blocked LUT micro-kernel on
//!                          the flat weight layout;
//! * `fused_lut_pk_dp_*`  — the same kernel traversing the tile-major
//!                          prepacked layout (`PackedLinear`, built
//!                          once outside the timing loop — exactly how
//!                          the serving plan cache amortizes it).
//!
//! A second trio (`*_splitk4_*`) repeats the comparison under the
//! SplitK decomposition for the decode-relevant skinny shapes; the
//! legacy kernel has no SplitK wrapper anymore, so that trio compares
//! LUT flat vs LUT prepacked only.
//!
//! Results land in `BENCH_microkernel.json` at the repo root
//! (`BENCH_microkernel_smoke.json` under `--smoke`, the CI mode).
//!
//! ```sh
//! cargo bench --bench microkernel [-- --smoke]
//! ```

use std::time::Duration;

use splitk_w4a16::kernels::{fused_gemm_legacy, host_gemm_into,
                            host_gemm_packed_into, HostKernelConfig,
                            KernelLayout, PackedLinear, SplitKScratch};
use splitk_w4a16::quant::{quantize_weight, MatF32};
use splitk_w4a16::util::{Bench, Rng};

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let nks: &[usize] = if smoke { &[2048] } else { &[2048, 4096, 8192] };
    let mut bench = if smoke {
        Bench::new(Duration::from_millis(200), 8, 1)
    } else {
        Bench::new(Duration::from_millis(600), 24, 1)
    };
    let mut rng = Rng::seed_from(23);
    let threads = splitk_w4a16::kernels::available_cores();
    let tiles = HostKernelConfig::host_tiles();
    println!("micro-kernel generations ({threads} worker threads, tiles \
              {}x{}x{}, group 128)",
             tiles.block_m, tiles.block_n, tiles.block_k);

    let mut lines = Vec::new();
    for &nk in nks {
        let q = {
            let w = MatF32::new(nk, nk, rng.normal_vec(nk * nk, 0.05));
            quantize_weight(&w, 128)
        };
        // Built once, outside every timing window (the serving path
        // builds it at plan-warm time).
        let pack = PackedLinear::new(&q, tiles.block_n as usize);
        for &m in &[1usize, 16] {
            let a = MatF32::new(
                m, nk,
                (0..m * nk).map(|_| rng.uniform_f32(-1.0, 1.0)).collect());

            let dp_cfg =
                HostKernelConfig::dp().with_tiles(tiles).with_threads(threads);
            let legacy = bench
                .run(&format!("legacy_dp_m{m}_nk{nk}"), || {
                    std::hint::black_box(fused_gemm_legacy(&a, &q, &dp_cfg));
                })
                .p50_ns;

            // The LUT series measure the scratch-reusing entry points —
            // the decode loop's steady state (one warmup run inside
            // Bench sizes the buffers before sampling starts).
            let mut scratch = SplitKScratch::new();
            let mut out = MatF32::zeros(m, nk);
            let lut = bench
                .run(&format!("fused_lut_dp_m{m}_nk{nk}"), || {
                    host_gemm_into(&a, &q, &dp_cfg, &mut scratch, &mut out);
                    std::hint::black_box(&out);
                })
                .p50_ns;

            let pk_cfg = dp_cfg.with_layout(KernelLayout::Prepacked);
            let lut_pk = bench
                .run(&format!("fused_lut_pk_dp_m{m}_nk{nk}"), || {
                    host_gemm_packed_into(&a, &q, &pack, &pk_cfg,
                                          &mut scratch, &mut out);
                    std::hint::black_box(&out);
                })
                .p50_ns;

            let sk_cfg = HostKernelConfig::splitk(4)
                .with_tiles(tiles)
                .with_threads(threads);
            let sk_lut = bench
                .run(&format!("fused_lut_splitk4_m{m}_nk{nk}"), || {
                    host_gemm_into(&a, &q, &sk_cfg, &mut scratch, &mut out);
                    std::hint::black_box(&out);
                })
                .p50_ns;
            let sk_pk_cfg = sk_cfg.with_layout(KernelLayout::Prepacked);
            let sk_lut_pk = bench
                .run(&format!("fused_lut_pk_splitk4_m{m}_nk{nk}"), || {
                    host_gemm_packed_into(&a, &q, &pack, &sk_pk_cfg,
                                          &mut scratch, &mut out);
                    std::hint::black_box(&out);
                })
                .p50_ns;

            lines.push(format!(
                "m={m:>2} n=k={nk:>5}: legacy/LUT {:>5.2}x   legacy/LUT+pk \
                 {:>5.2}x   splitk4 LUT/LUT+pk {:>5.2}x",
                legacy / lut, legacy / lut_pk, sk_lut / sk_lut_pk));
        }
    }

    println!("── micro-kernel speedups (p50) ───────────────────────────");
    for l in &lines {
        println!("{l}");
    }

    let out = if smoke { "BENCH_microkernel_smoke.json" }
              else { "BENCH_microkernel.json" };
    match bench.write_repo_root_json(out) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
