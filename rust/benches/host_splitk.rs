//! Bench: the executable fused W4A16 host backend across the paper's
//! sweep — m ∈ {1, 16}, n = k ∈ {2048, 4096, 8192} — comparing:
//!
//! * `naive_ref`       — `quant::w4a16_gemm_ref` (materializes the dense
//!                       f32 weight, then dense GEMM; what every consumer
//!                       paid before the exec backend landed);
//! * `fused_dp`        — `kernels::exec::fused_gemm_dp`;
//! * `fused_splitk{S}` — `kernels::exec::fused_gemm_splitk`,
//!                       S ∈ {1, 2, 4, 8};
//! * `fused_streamk{W}` — `kernels::exec::fused_gemm_streamk`,
//!                       W ∈ {2, 4, 8} persistent spans — the third
//!                       decomposition family, added with the StreamK
//!                       host executor.
//!
//! All fused variants run the paper's tile config so only the
//! decomposition differs (the paper's own controlled comparison).
//! Results land in `BENCH_host_splitk.json` at the repo root — the
//! perf-trajectory record future PRs regress against (EXPERIMENTS.md).
//!
//! ```sh
//! cargo bench --bench host_splitk [-- --smoke]
//! ```
//!
//! `--smoke` restricts the sweep to one shape pair (m ∈ {1, 16},
//! n = k = 2048) with a short budget and writes
//! `BENCH_host_splitk_smoke.json` instead — the CI mode that exercises
//! the bench (including the StreamK series) without paying for (or
//! clobbering) the full-grid trajectory record.

use std::time::Duration;

use splitk_w4a16::kernels::{fused_gemm_dp, fused_gemm_splitk,
                            fused_gemm_streamk, HostKernelConfig, TileConfig};
use splitk_w4a16::quant::{quantize_weight, w4a16_gemm_ref, MatF32};
use splitk_w4a16::util::{Bench, Rng};

const SPLITS: [u32; 4] = [1, 2, 4, 8];
const STREAMK_WORKERS: [u32; 3] = [2, 4, 8];

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let nks: &[usize] = if smoke { &[2048] } else { &[2048, 4096, 8192] };
    let mut bench = if smoke {
        Bench::new(Duration::from_millis(200), 8, 1)
    } else {
        Bench::new(Duration::from_millis(600), 24, 1)
    };
    let mut rng = Rng::seed_from(17);
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    // Paper tile config for every variant: decomposition isolated.
    let tiles = TileConfig::paper_splitk();
    println!("fused W4A16 host backend sweep ({threads} worker threads, \
              tiles {}x{}x{})",
             tiles.block_m, tiles.block_n, tiles.block_k);

    let mut lines = Vec::new();
    for &nk in nks {
        let q = {
            let w = MatF32::new(nk, nk, rng.normal_vec(nk * nk, 0.05));
            quantize_weight(&w, 128)
        };
        for &m in &[1usize, 16] {
            let a = MatF32::new(
                m, nk,
                (0..m * nk).map(|_| rng.uniform_f32(-1.0, 1.0)).collect());

            let naive = bench
                .run(&format!("naive_ref_m{m}_nk{nk}"), || {
                    std::hint::black_box(w4a16_gemm_ref(&a, &q));
                })
                .p50_ns;

            let dp_cfg =
                HostKernelConfig::dp().with_tiles(tiles).with_threads(threads);
            let dp = bench
                .run(&format!("fused_dp_m{m}_nk{nk}"), || {
                    std::hint::black_box(fused_gemm_dp(&a, &q, &dp_cfg));
                })
                .p50_ns;

            let mut best_sk = f64::MAX;
            let mut best_split = 1u32;
            for &split in &SPLITS {
                let cfg = HostKernelConfig::splitk(split)
                    .with_tiles(tiles)
                    .with_threads(threads);
                let t = bench
                    .run(&format!("fused_splitk{split}_m{m}_nk{nk}"), || {
                        std::hint::black_box(fused_gemm_splitk(&a, &q, &cfg));
                    })
                    .p50_ns;
                if t < best_sk {
                    best_sk = t;
                    best_split = split;
                }
            }

            // Third series: StreamK persistent spans over the flattened
            // (n-tile x k-slice) iteration space.
            let mut best_st = f64::MAX;
            let mut best_workers = STREAMK_WORKERS[0];
            for &workers in &STREAMK_WORKERS {
                let cfg = HostKernelConfig::streamk(workers)
                    .with_tiles(tiles)
                    .with_threads(threads);
                let t = bench
                    .run(&format!("fused_streamk{workers}_m{m}_nk{nk}"), || {
                        std::hint::black_box(fused_gemm_streamk(&a, &q, &cfg));
                    })
                    .p50_ns;
                if t < best_st {
                    best_st = t;
                    best_workers = workers;
                }
            }

            lines.push(format!(
                "m={m:>2} n=k={nk:>5}: naive/DP {:>6.2}x   naive/SplitK \
                 {:>6.2}x (best split {best_split})   naive/StreamK \
                 {:>6.2}x (best workers {best_workers})   DP/SplitK \
                 {:>5.2}x   DP/StreamK {:>5.2}x",
                naive / dp, naive / best_sk, naive / best_st, dp / best_sk,
                dp / best_st));
        }
    }

    println!("── speedups (p50) ────────────────────────────────────────");
    for l in &lines {
        println!("{l}");
    }

    // Smoke runs write a separate file so a local `-- --smoke` never
    // clobbers the canonical full-sweep trajectory record.
    let out = if smoke { "BENCH_host_splitk_smoke.json" }
              else { "BENCH_host_splitk.json" };
    match bench.write_repo_root_json(out) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
