//! Bench: the fused W4A16 kernels on the CPU.
//!
//! Two tiers:
//!
//! * **Host exec backend** (`kernels::exec`) — always runs, no artifacts
//!   needed: fused-DP and fused-SplitK vs the naive
//!   materialize-then-GEMM reference on small shapes. (The full paper
//!   sweep lives in `benches/host_splitk.rs`.)
//! * **AOT Pallas -> PJRT CPU artifacts** — SplitK vs Data-Parallel from
//!   the same artifacts the serving path uses; skipped when
//!   `artifacts/manifest.json` is absent (run `make artifacts`).

use std::path::PathBuf;

use splitk_w4a16::kernels::{fused_gemm_dp, fused_gemm_splitk,
                            HostKernelConfig};
use splitk_w4a16::quant::{quantize_weight, w4a16_gemm_ref, MatF32};
use splitk_w4a16::runtime::{ExecutableCache, HostTensor, Manifest, Runtime};
use splitk_w4a16::util::{Bench, Rng};

fn host_backend(bench: &mut Bench, rng: &mut Rng) {
    for (m, nk) in [(1usize, 512usize), (16, 512), (16, 1024)] {
        let q = {
            let w = MatF32::new(nk, nk, rng.normal_vec(nk * nk, 0.05));
            quantize_weight(&w, 128)
        };
        let a = MatF32::new(
            m, nk, (0..m * nk).map(|_| rng.uniform_f32(-1.0, 1.0)).collect());
        bench.run(&format!("host_naive_ref_m{m}_nk{nk}"), || {
            std::hint::black_box(w4a16_gemm_ref(&a, &q));
        });
        let dp = HostKernelConfig::dp();
        bench.run(&format!("host_fused_dp_m{m}_nk{nk}"), || {
            std::hint::black_box(fused_gemm_dp(&a, &q, &dp));
        });
        let sk = HostKernelConfig::splitk(4);
        bench.run(&format!("host_fused_splitk4_m{m}_nk{nk}"), || {
            std::hint::black_box(fused_gemm_splitk(&a, &q, &sk));
        });
    }
}

fn pjrt_artifacts(bench: &mut Bench, rng: &mut Rng, dir: PathBuf) {
    let manifest = Manifest::load(&dir).expect("manifest");
    let shapes = manifest.gemm_shapes("splitk");
    let runtime = Runtime::cpu().expect("pjrt");
    let mut cache = ExecutableCache::new(runtime, manifest);

    for (m, n, k) in shapes {
        let entry_sk = cache.manifest().find_gemm("splitk", m, n, k)
            .unwrap().clone();
        let entry_dp = match cache.manifest().find_gemm("dp", m, n, k) {
            Ok(e) => e.clone(),
            Err(_) => continue,
        };
        let group = entry_sk.group_size.unwrap();
        let a: Vec<f32> = (0..m * k).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();
        let w = MatF32::new(k, n, rng.normal_vec(k * n, 0.05));
        let q = quantize_weight(&w, group);
        let inputs = [
            HostTensor::f32(vec![m, k], a),
            HostTensor::i32(vec![q.qweight.rows, q.qweight.cols],
                            q.qweight.data.clone()),
            HostTensor::f32(vec![q.scales.rows, q.scales.cols],
                            q.scales.data.clone()),
            HostTensor::i32(vec![q.qzeros.rows, q.qzeros.cols],
                            q.qzeros.data.clone()),
        ];
        let sk = cache.get(&entry_sk).unwrap();
        bench.run(&format!("gemm_splitk_m{m}_nk{n}"), || {
            sk.run(&inputs).unwrap();
        });
        let dp = cache.get(&entry_dp).unwrap();
        bench.run(&format!("gemm_dp_m{m}_nk{n}"), || {
            dp.run(&inputs).unwrap();
        });
    }
}

fn main() {
    let mut bench = Bench::quick();
    let mut rng = Rng::seed_from(11);

    host_backend(&mut bench, &mut rng);

    let dir = PathBuf::from("artifacts");
    if dir.join("manifest.json").exists() {
        pjrt_artifacts(&mut bench, &mut rng, dir);
    } else {
        eprintln!("skipping PJRT artifact benches: run `make artifacts` first");
    }

    std::fs::create_dir_all("results").ok();
    bench.write_json("results/bench_kernel_cpu.json").ok();
}
