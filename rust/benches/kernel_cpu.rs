//! Bench: the REAL fused W4A16 kernels (AOT Pallas -> PJRT CPU), SplitK
//! vs Data-Parallel, across the paper's m ∈ {1, 16} and n = k sweep —
//! the real-numerics counterpart of Tables 1–6. Absolute times are
//! CPU-PJRT (interpret-lowered) and not GPU-comparable; what matters is
//! that both variants run the identical math from the same artifacts.
//!
//! Skips (exit 0) if artifacts are not built.

use std::path::PathBuf;

use splitk_w4a16::quant::{quantize_weight, MatF32};
use splitk_w4a16::runtime::{ExecutableCache, HostTensor, Manifest, Runtime};
use splitk_w4a16::util::{Bench, Rng};

fn main() {
    let dir = PathBuf::from("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping kernel_cpu bench: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(&dir).expect("manifest");
    let shapes = manifest.gemm_shapes("splitk");
    let runtime = Runtime::cpu().expect("pjrt");
    let mut cache = ExecutableCache::new(runtime, manifest);
    let mut bench = Bench::quick();
    let mut rng = Rng::seed_from(11);

    for (m, n, k) in shapes {
        let entry_sk = cache.manifest().find_gemm("splitk", m, n, k)
            .unwrap().clone();
        let entry_dp = match cache.manifest().find_gemm("dp", m, n, k) {
            Ok(e) => e.clone(),
            Err(_) => continue,
        };
        let group = entry_sk.group_size.unwrap();
        let a: Vec<f32> = (0..m * k).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();
        let w = MatF32::new(k, n, rng.normal_vec(k * n, 0.05));
        let q = quantize_weight(&w, group);
        let inputs = [
            HostTensor::f32(vec![m, k], a),
            HostTensor::i32(vec![q.qweight.rows, q.qweight.cols],
                            q.qweight.data.clone()),
            HostTensor::f32(vec![q.scales.rows, q.scales.cols],
                            q.scales.data.clone()),
            HostTensor::i32(vec![q.qzeros.rows, q.qzeros.cols],
                            q.qzeros.data.clone()),
        ];
        let sk = cache.get(&entry_sk).unwrap();
        bench.run(&format!("gemm_splitk_m{m}_nk{n}"), || {
            sk.run(&inputs).unwrap();
        });
        let dp = cache.get(&entry_dp).unwrap();
        bench.run(&format!("gemm_dp_m{m}_nk{n}"), || {
            dp.run(&inputs).unwrap();
        });
    }
    std::fs::create_dir_all("results").ok();
    bench.write_json("results/bench_kernel_cpu.json").ok();
}
