//! Bench: regenerate Tables 1–6 (Figures 3–8) on the calibrated
//! simulator — one benchmark per paper table, timing the full n=k sweep
//! and printing mean/peak speedups so the bench log doubles as the
//! experiment record.

use splitk_w4a16::gpusim::DeviceConfig;
use splitk_w4a16::tables::tflops_table;
use splitk_w4a16::util::Bench;

fn main() {
    let mut bench = Bench::default();
    let specs = [
        ("table1_a100_40_m1", DeviceConfig::a100_40gb_pcie(), 1u64),
        ("table2_a100_80_m1", DeviceConfig::a100_80gb_sxm(), 1),
        ("table3_h100_m1", DeviceConfig::h100_pcie(), 1),
        ("table4_a100_40_m16", DeviceConfig::a100_40gb_pcie(), 16),
        ("table5_a100_80_m16", DeviceConfig::a100_80gb_sxm(), 16),
        ("table6_h100_m16", DeviceConfig::h100_pcie(), 16),
    ];
    for (name, dev, m) in specs {
        let mut last = None;
        bench.run(name, || {
            last = Some(tflops_table(&dev, m));
        });
        let t = last.unwrap();
        println!(
            "    -> mean speedup {:.2}x  peak {:.2}x  (splitk wins {}/{} rows)",
            t.mean_speedup(),
            t.peak_speedup(),
            t.rows.iter().filter(|r| r.speedup > 1.0).count(),
            t.rows.len()
        );
    }
    std::fs::create_dir_all("results").ok();
    bench.write_json("results/bench_paper_tables.json").ok();
    // Canonical perf-trajectory record at the repo root (same format as
    // BENCH_host_splitk.json; future PRs regress against these).
    match bench.write_repo_root_json("BENCH_paper_tables.json") {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write BENCH_paper_tables.json: {e}"),
    }
}
