//! Bench: Figures 9/10 — the split_k factor sweep on A100 and H100, plus
//! the autotuner that consumes it. Prints the best factor per device
//! (paper §3.3: 4 on A100, 8 on H100).

use splitk_w4a16::gpusim::DeviceConfig;
use splitk_w4a16::kernels::{autotune_split_k, GemmShape, TileConfig};
use splitk_w4a16::tables::split_factor_sweep;
use splitk_w4a16::util::Bench;

fn main() {
    let mut bench = Bench::default();
    for (name, dev) in [
        ("figure9_split_sweep_a100", DeviceConfig::a100_80gb_sxm()),
        ("figure10_split_sweep_h100", DeviceConfig::h100_pcie()),
    ] {
        let mut last = None;
        bench.run(name, || {
            last = Some(split_factor_sweep(&dev, 16));
        });
        println!("    -> best split_k = {}", last.unwrap().best_split_k());
    }

    let tiles = TileConfig::paper_splitk();
    for dev in DeviceConfig::paper_devices() {
        let shape = GemmShape::square(16, 4096);
        let mut best = 0;
        bench.run(&format!("autotune_4096_{}", dev.name.replace(' ', "_")), || {
            best = autotune_split_k(&dev, &shape, &tiles)
                .expect("paper shape is feasible")
                .best_split_k;
        });
        println!("    -> best split_k at n=k=4096: {best}");
    }
    std::fs::create_dir_all("results").ok();
    bench.write_json("results/bench_splitk_factor.json").ok();
}
