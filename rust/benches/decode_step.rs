//! Bench: one full decode step of the pure-Rust host model per batch
//! bucket — the batcher's bucket choice *is* the `m` of every fused
//! W4A16 projection in the step, so this sweep is the serving-side view
//! of the paper's m = 1..16 skinny-GEMM regime.
//!
//! Per-shape kernel configs come from the wall-clock autotuner (same as
//! serving). Results land in `BENCH_decode.json` at the repo root, the
//! decode-path perf-trajectory record (DESIGN.md §8).
//!
//! ```sh
//! cargo bench --bench decode_step [-- --smoke]
//! ```

use std::time::Duration;

use splitk_w4a16::model::HostModel;
use splitk_w4a16::runtime::ModelMeta;
use splitk_w4a16::util::Bench;

/// Attention window depth the measured step runs at.
const POS: usize = 16;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let buckets: &[usize] = if smoke { &[1, 16] } else { &[1, 2, 4, 8, 16] };
    let meta = ModelMeta::synthetic(128, "splitk", vec![1, 2, 4, 8, 16], 0);
    let mut model = HostModel::new(&meta).expect("host model");
    let planned = model.warm(&meta.batch_buckets);
    println!("host decode model ready ({planned} bucket-shapes autotuned, \
              {:.1} MB packed weights)",
             model.weights().packed_bytes() as f64 / 1e6);

    let mut bench = if smoke {
        Bench::new(Duration::from_millis(250), 12, 1)
    } else {
        Bench::new(Duration::from_millis(800), 48, 2)
    };
    for &b in buckets {
        let starts = vec![0i32; b];
        let mut state = model.begin(&starts);
        // Prefill 0..POS so the measured step attends over a realistic
        // window.
        for pos in 0..POS {
            let tokens: Vec<i32> =
                (0..b).map(|i| ((7 * pos + i) % 512) as i32).collect();
            // Prefill fast path: logits discarded, LM head skipped.
            model
                .decode_step(&mut state, &tokens, pos, false)
                .expect("prefill");
        }
        let tokens: Vec<i32> =
            (0..b).map(|i| ((3 * i + 11) % 512) as i32).collect();
        bench.run(&format!("decode_step_b{b}"), || {
            // Re-running the same position keeps the GEMM shapes and
            // attention span constant across samples.
            std::hint::black_box(
                model
                    .decode_step(&mut state, &tokens, POS, true)
                    .expect("step"));
        });
    }

    // Smoke runs write a separate file so a local `-- --smoke` never
    // clobbers the canonical full-sweep trajectory record.
    let out = if smoke { "BENCH_decode_smoke.json" }
              else { "BENCH_decode.json" };
    match bench.write_repo_root_json(out) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
