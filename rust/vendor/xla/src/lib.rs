//! Vendored stand-in for the `xla` (xla_extension / PJRT) bindings.
//!
//! The real crate wraps libxla_extension, which is not present in this
//! offline environment (DESIGN.md §2). This stand-in keeps the same API
//! surface the workspace uses, split into two tiers:
//!
//! * **Functional**: [`Literal`] is a real host-side typed tensor —
//!   `vec1`, `reshape`, `array_shape`, `to_vec`, `to_tuple` all work, so
//!   `HostTensor <-> Literal` round-trips (and their tests) run without
//!   PJRT.
//! * **Unavailable**: compiling or executing an HLO module needs the
//!   native runtime, so [`PjRtClient::compile`] and
//!   [`PjRtLoadedExecutable::execute`] return a descriptive [`Error`].
//!   Callers that gate on `artifacts/manifest.json` skip before reaching
//!   them.
//!
//! Swap this path dependency for the real bindings in `rust/Cargo.toml`
//! to serve actual artifacts; no workspace code changes.

use std::fmt;
use std::path::Path;

/// Error type mirroring the real crate's (implements `std::error::Error`,
/// so `?` converts it into `anyhow::Error`).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: the native XLA/PJRT runtime is not available in this \
         build (vendored stub — see rust/Cargo.toml and DESIGN.md §2)"
    ))
}

/// Element dtypes the manifest format can name.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    U8,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
}

/// Dims + dtype of an array literal.
#[derive(Debug, Clone, PartialEq)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

#[derive(Debug, Clone, PartialEq)]
enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// A host-side typed tensor (or tuple of tensors) — fully functional.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    payload: Payload,
}

/// Rust scalar types that map onto an XLA element type.
pub trait NativeType: Copy {
    #[doc(hidden)]
    fn literal_from_vec(data: Vec<Self>, dims: Vec<i64>) -> Literal;
    #[doc(hidden)]
    fn extract(lit: &Literal) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn literal_from_vec(data: Vec<Self>, dims: Vec<i64>) -> Literal {
        Literal { dims, payload: Payload::F32(data) }
    }
    fn extract(lit: &Literal) -> Option<Vec<Self>> {
        match &lit.payload {
            Payload::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn literal_from_vec(data: Vec<Self>, dims: Vec<i64>) -> Literal {
        Literal { dims, payload: Payload::I32(data) }
    }
    fn extract(lit: &Literal) -> Option<Vec<Self>> {
        match &lit.payload {
            Payload::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        T::literal_from_vec(data.to_vec(), vec![data.len() as i64])
    }

    /// Tuple literal from parts.
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { dims: vec![], payload: Payload::Tuple(parts) }
    }

    fn element_count(&self) -> usize {
        self.dims.iter().map(|&d| d as usize).product()
    }

    /// Same data, new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: usize = dims.iter().map(|&d| d as usize).product();
        if matches!(self.payload, Payload::Tuple(_)) {
            return Err(Error("cannot reshape a tuple literal".into()));
        }
        if want != self.element_count() {
            return Err(Error(format!(
                "reshape: {:?} -> {:?} changes element count",
                self.dims, dims
            )));
        }
        Ok(Literal { dims: dims.to_vec(), payload: self.payload.clone() })
    }

    /// Dims + dtype; errors on tuple literals.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        let ty = match &self.payload {
            Payload::F32(_) => ElementType::F32,
            Payload::I32(_) => ElementType::S32,
            Payload::Tuple(_) => {
                return Err(Error("tuple literal has no array shape".into()))
            }
        };
        Ok(ArrayShape { dims: self.dims.clone(), ty })
    }

    /// Copy the elements out as a typed vec.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(self)
            .ok_or_else(|| Error("literal dtype mismatch in to_vec".into()))
    }

    /// Decompose a tuple literal into its parts.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.payload {
            Payload::Tuple(parts) => Ok(parts.clone()),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }
}

/// Parsed HLO module (stub: existence-checked, contents opaque).
pub struct HloModuleProto {
    _text_len: usize,
}

impl HloModuleProto {
    /// Read an HLO text file; parsing is deferred to the (absent) native
    /// runtime, so this only validates that the file is readable.
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| Error(format!("reading HLO text: {e}")))?;
        Ok(HloModuleProto { _text_len: text.len() })
    }
}

/// An XLA computation handle.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// PJRT client handle. Construction succeeds (host tensors work without
/// the native runtime); compilation does not.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _priv: () })
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub (no native PJRT)".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// A compiled executable handle (never constructed by the stub client).
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer handle (never constructed by the stub client).
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = lit.reshape(&[2, 3]).unwrap();
        let shape = r.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 3]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(r.to_vec::<f32>().unwrap().len(), 6);
        assert!(r.to_vec::<i32>().is_err());
        assert!(lit.reshape(&[7]).is_err());
    }

    #[test]
    fn scalar_reshape() {
        let lit = Literal::vec1(&[42i32]).reshape(&[]).unwrap();
        let shape = lit.array_shape().unwrap();
        assert!(shape.dims().is_empty());
        assert_eq!(shape.ty(), ElementType::S32);
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![42]);
    }

    #[test]
    fn tuple_literals() {
        let t = Literal::tuple(vec![
            Literal::vec1(&[1.0f32]),
            Literal::vec1(&[2i32]),
        ]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(t.array_shape().is_err());
        assert!(parts[0].to_tuple().is_err());
    }

    #[test]
    fn runtime_is_gated() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client.platform_name().contains("stub"));
        let comp = XlaComputation::from_proto(&HloModuleProto { _text_len: 0 });
        assert!(client.compile(&comp).is_err());
    }
}
