//! Vendored stand-in for the `anyhow` crate.
//!
//! This environment is offline with a fixed crate set (DESIGN.md §2), so
//! the workspace ships the small subset of anyhow's API that the code
//! actually uses: [`Error`], [`Result`], the [`Context`] extension trait,
//! and the `anyhow!` / `bail!` / `ensure!` macros. Error values carry a
//! flattened message chain (context-prefixed, source-suffixed) rather
//! than a structured cause chain — enough for every call site here.

use std::fmt;

/// A flattened error: the accumulated context/message chain as text.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string() }
    }

    /// Prefix the error with higher-level context (anyhow's chain order:
    /// outermost context first).
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error` — that is what makes this blanket conversion
// coherent alongside the reflexive `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    /// Attach a context message to the error, if any.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Attach a lazily-built context message to the error, if any.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_prefixes() {
        let r: Result<()> = Err(io_err()).context("reading file");
        assert_eq!(r.unwrap_err().to_string(), "reading file: gone");
    }

    #[test]
    fn with_context_is_lazy() {
        let mut calls = 0;
        let ok: Result<i32> = Ok::<_, Error>(3).with_context(|| {
            calls += 1;
            "ctx"
        });
        assert_eq!(ok.unwrap(), 3);
        assert_eq!(calls, 0, "context closure must not run on Ok");
        let err: Result<i32> = Err(io_err()).with_context(|| "outer");
        assert_eq!(err.unwrap_err().to_string(), "outer: gone");
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x == 1 {
                bail!("one is not allowed");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative: -1");
        assert_eq!(f(1).unwrap_err().to_string(), "one is not allowed");
        let e = anyhow!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/path")?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
