//! Vendored stand-in for the `log` facade crate.
//!
//! Implements the subset this workspace uses (DESIGN.md §2): the five
//! level macros, [`Level`]/[`LevelFilter`], [`Record`]/[`Metadata`], the
//! [`Log`] trait, and the global `set_logger` / `set_max_level` pair.
//! The workspace's own backend lives in `splitk_w4a16::util::logging`.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Severity of one log record (most to least severe).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Global verbosity filter ([`Level`] plus `Off`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Level + target of a record, shown to [`Log::enabled`].
#[derive(Debug, Clone, Copy)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record: metadata + the pre-formatted arguments.
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A logging backend.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);
static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();

/// Returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

/// Install the global logger (first caller wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global verbosity ceiling.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// Current global verbosity ceiling.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

/// Macro plumbing — not public API.
#[doc(hidden)]
pub fn __private_api_log(level: Level, target: &str, args: fmt::Arguments) {
    if (level as usize) > MAX_LEVEL.load(Ordering::Relaxed) {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let metadata = Metadata { level, target };
        if logger.enabled(&metadata) {
            logger.log(&Record { metadata, args });
        }
    }
}

/// Log at an explicit level.
#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__private_api_log($lvl, module_path!(), format_args!($($arg)+))
    };
}

/// Log at `Level::Error`.
#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

/// Log at `Level::Warn`.
#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

/// Log at `Level::Info`.
#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

/// Log at `Level::Debug`.
#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

/// Log at `Level::Trace`.
#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    static HITS: AtomicUsize = AtomicUsize::new(0);

    struct Counter;

    impl Log for Counter {
        fn enabled(&self, _: &Metadata) -> bool {
            true
        }
        fn log(&self, record: &Record) {
            assert!(!record.target().is_empty());
            let _ = format!("{}", record.args());
            HITS.fetch_add(1, Ordering::Relaxed);
        }
        fn flush(&self) {}
    }

    #[test]
    fn filter_and_dispatch() {
        let _ = set_logger(&Counter);
        set_max_level(LevelFilter::Info);
        assert_eq!(max_level(), LevelFilter::Info);
        let before = HITS.load(Ordering::Relaxed);
        info!("hello {}", 1);
        debug!("filtered out {}", 2);
        let after = HITS.load(Ordering::Relaxed);
        assert_eq!(after - before, 1);
    }
}
