"""Python mirror of `rust/src/coordinator/sampler.rs`.

Re-implements the committed sampling algorithm — PCG32 stream, candidate
ordering, f32 softmax weights, top-k / top-p truncation, inverse-CDF
walk — and pins the *same* known-answer vectors the Rust unit tests
assert, so the two implementations are cross-validated without either
executing the other:

* the PCG32 reference vectors (``srandom(42, 54)`` -> ``0xa15c02b7 ...``
  from the canonical pcg32-demo output) pin the RNG integer-exactly;
* the token-stream vectors pin the full sampling pipeline; every pinned
  case was chosen with an inverse-CDF decision margin >= 1.7e-3
  relative, orders of magnitude above any libm ``exp`` last-ulp
  divergence, so the streams are machine-portable;
* the invariants (same seed => same stream under interleaving, top-k
  support, top-p mass, temperature -> 0 => greedy) hold structurally.

Run: ``python -m pytest python/tests/test_sampler_mirror.py`` (plain
``python python/tests/test_sampler_mirror.py`` also works).
"""

import math

import numpy as np

MASK64 = (1 << 64) - 1
PCG_MULT = 6364136223846793005


class Pcg32:
    """Mirror of ``sampler::Pcg32`` (PCG32 XSH RR, reference seeding)."""

    def __init__(self, initstate, initseq=0):
        self.state = 0
        self.inc = ((initseq << 1) | 1) & MASK64
        self.next_u32()
        self.state = (self.state + initstate) & MASK64
        self.next_u32()

    def next_u32(self):
        old = self.state
        self.state = (old * PCG_MULT + self.inc) & MASK64
        xorshifted = (((old >> 18) ^ old) >> 27) & 0xFFFFFFFF
        rot = old >> 59
        return ((xorshifted >> rot) | (xorshifted << ((-rot) & 31))) \
            & 0xFFFFFFFF

    def next_f32(self):
        # Top 24 bits / 2^24: exactly representable in f32.
        return np.float32(self.next_u32() >> 8) / np.float32(1 << 24)


def argmax(row):
    """Mirror of ``engine::argmax``: strict ``>`` from -inf, so ties
    break to the lowest index and NaN never wins; no winner -> 0."""
    best, best_v = 0, np.float32(-np.inf)
    for i, v in enumerate(row):
        if v > best_v:
            best_v, best = v, i
    return best


class Sampler:
    """Mirror of ``sampler::Sampler::next_token`` (committed f32 order)."""

    def __init__(self, temperature, top_k, top_p, seed):
        self.temperature = np.float32(temperature)
        self.top_k = top_k
        self.top_p = np.float32(top_p)
        self.rng = Pcg32(seed)

    def next_token(self, logits):
        logits = [np.float32(x) for x in logits]
        if self.temperature == np.float32(0.0):
            return argmax(logits)
        u = self.rng.next_f32()
        cand = [(l, i) for i, l in enumerate(logits) if math.isfinite(l)]
        if not cand:
            return argmax(logits)
        cand.sort(key=lambda p: (-p[0], p[1]))
        if self.top_k > 0:
            cand = cand[:self.top_k]
        mx = cand[0][0]
        w = [np.float32(np.exp(np.float32(
            np.float32(l - mx) / self.temperature))) for l, _ in cand]
        total = np.float32(0.0)
        for x in w:
            total = np.float32(total + x)
        kept = len(w)
        if self.top_p < np.float32(1.0):
            thresh = np.float32(self.top_p * total)
            acc = np.float32(0.0)
            kept = 0
            for x in w:
                acc = np.float32(acc + x)
                kept += 1
                if acc >= thresh:
                    break
            total = acc
        target = np.float32(u * total)
        acc = np.float32(0.0)
        for i in range(kept):
            acc = np.float32(acc + w[i])
            if target < acc:
                return cand[i][1]
        return cand[kept - 1][1]


def stream(logits, t, k, p, seed, n):
    s = Sampler(t, k, p, seed)
    return [s.next_token(logits) for _ in range(n)]


R8 = [0.5, 2.5, -1.0, 2.4, 0.0, 1.5, -3.0, 1.0]
TIE = [1.0, 3.0, 3.0, 0.5]
NAN_ROW = [float("nan"), 2.0, 1.0, float("-inf"), 1.9]


# ---- PCG32 known answers (same constants as the Rust tests) -----------

def test_pcg32_matches_reference_vectors():
    r = Pcg32(42, 54)
    want = [0xA15C02B7, 0x7B47F409, 0xBA1D3330, 0x83D2F293, 0xBFA4784B,
            0xCBED606E]
    assert [r.next_u32() for _ in range(6)] == want


def test_pcg32_seed_from_vectors():
    r0 = Pcg32(0)
    assert [r0.next_u32() for _ in range(4)] == \
        [3837872008, 932996374, 1548399547, 1612522464]
    r7 = Pcg32(7)
    assert [r7.next_u32() for _ in range(4)] == \
        [4063834449, 2143014202, 2740157135, 3385478207]


# ---- argmax contract ---------------------------------------------------

def test_argmax_contract():
    assert argmax([0.1, 0.9, 0.5]) == 1
    assert argmax([2.0, 2.0]) == 0
    assert argmax([float("nan"), 1.0, 2.0]) == 2
    assert argmax([float("nan"), float("nan")]) == 0
    assert argmax([float("-inf")] * 4) == 0


# ---- cross-language known-answer streams -------------------------------

def test_known_answer_streams_match_rust():
    assert stream(R8, 1.0, 0, 1.0, 1, 8) == [7, 1, 5, 1, 3, 3, 3, 5]
    assert stream(R8, 1.0, 0, 1.0, 9, 8) == [3, 3, 3, 3, 3, 3, 1, 1]
    assert stream(R8, 0.7, 0, 1.0, 1, 8) == [5, 1, 5, 1, 3, 3, 3, 3]
    assert stream(R8, 1.0, 3, 1.0, 1, 8) == [5, 1, 3, 1, 3, 3, 3, 3]
    assert stream(R8, 1.0, 0, 0.8, 1, 8) == [5, 1, 3, 1, 3, 3, 3, 3]
    assert stream(R8, 1.5, 4, 0.9, 1, 8) == [7, 1, 5, 1, 3, 3, 3, 5]
    assert stream(TIE, 1.0, 2, 1.0, 1, 8) == [2, 1, 2, 1, 2, 2, 2, 2]
    assert stream(NAN_ROW, 1.0, 0, 1.0, 1, 8) == [2, 1, 4, 1, 4, 4, 4, 4]
    assert stream(NAN_ROW, 0.5, 2, 0.9, 9, 8) == [1, 1, 4, 4, 4, 1, 1, 1]


# ---- invariants --------------------------------------------------------

def test_same_seed_same_stream_regardless_of_interleaving():
    rng = np.random.default_rng(100)
    rows = [list(rng.normal(size=16).astype(np.float32)) for _ in range(12)]
    solo = Sampler(0.9, 6, 0.95, 42)
    want = [solo.next_token(r) for r in rows]
    a = Sampler(0.9, 6, 0.95, 42)
    other = Sampler(0.9, 6, 0.95, 7)
    got = []
    for i, row in enumerate(rows):
        if i % 2 == 0:
            other.next_token(row)
        got.append(a.next_token(row))
        if i % 3 == 0:
            other.next_token(row)
    assert got == want


def test_top_k_restricts_support():
    s = Sampler(1.2, 3, 1.0, 5)
    for _ in range(300):
        assert s.next_token(R8) in (1, 3, 5)


def test_top_p_mass_invariant():
    # probs [0.5, 0.3, 0.2]; top_p = 0.7 keeps exactly {0, 1}: the
    # smallest prefix with mass >= 0.7, so kept mass 0.8 >= top_p.
    logits = [math.log(0.5), math.log(0.3), math.log(0.2)]
    s = Sampler(1.0, 0, 0.7, 3)
    seen = [0, 0, 0]
    for _ in range(500):
        seen[s.next_token(logits)] += 1
    assert seen[2] == 0
    assert seen[0] > 0 and seen[1] > 0


def test_tiny_temperature_converges_to_greedy():
    s = Sampler(1e-4, 0, 1.0, 11)
    for _ in range(200):
        assert s.next_token(R8) == argmax(R8)


def test_greedy_draws_nothing():
    s = Sampler(0.0, 0, 1.0, 0)
    for _ in range(5):
        assert s.next_token(R8) == argmax(R8)
    raw = Pcg32(0)
    assert s.rng.next_u32() == raw.next_u32()


def test_all_nonfinite_row_is_defined():
    s = Sampler(1.0, 0, 1.0, 1)
    assert s.next_token([float("nan"), float("-inf"), float("nan")]) == 0


if __name__ == "__main__":
    for name, fn in sorted(globals().items()):
        if name.startswith("test_") and callable(fn):
            fn()
            print(f"{name}: ok")
    print("all sampler mirror tests passed")
