"""Python mirror of the Rust register-blocked LUT micro-kernel (PR 4).

The growth container has no Rust toolchain (DESIGN.md §2), so — as with
the SplitK/StreamK mirrors of PRs 1 and 3 — this file re-implements the
*exact* loop structure of `rust/src/kernels/exec/microkernel.rs` in
numpy float32 (every multiply and add rounded to f32, like the Rust
f32 ops) and pins it bit-identical to a plain reference loop that
mirrors `exec/fused.rs::fused_tile`. What this validates:

* the per-(group, column) 16-entry LUT (`lut[v] = (v - zero) * scale`)
  substitutes bit-exactly for the in-loop dequant expression;
* the register-tile decomposition (16-column blocks x 4-row blocks with
  monomorphized remainders, accumulators "live" across a run and
  store back once) preserves every element's ascending-k operation
  chain;
* run boundaries (quant-group end, `block_k` chunk end, range end) and
  column segmentation at prepacked-panel boundaries are bit-neutral;
* the `PackedLinear` panel layout (tile-major words at closed-form
  offset `kp_total * p * block_n`, panel-major scale/zero streams)
  round-trips the flat tensors exactly, including ragged last panels.

Run: pytest python/tests/test_microkernel_mirror.py -q
"""

import numpy as np
import pytest

f32 = np.float32
PACK = 8


def quantize(rng, k, n, group):
    """Random W4 layer in the flat storage format (unpacked views)."""
    nib = rng.integers(0, 16, size=(k, n), dtype=np.int64)
    groups = k // group
    zeros = rng.integers(0, 16, size=(groups, n), dtype=np.int64)
    scales = rng.uniform(0.01, 0.3, size=(groups, n)).astype(f32)
    # Packed words exactly as pack_along_rows: nibble i of word kp is
    # weight row kp*8 + i, bits 4i..4i+3.
    kp_total = k // PACK
    words = np.zeros((kp_total, n), dtype=np.int64)
    for kp in range(kp_total):
        for i in range(PACK):
            words[kp] |= (nib[kp * PACK + i] & 0xF) << (4 * i)
    return nib, words, zeros, scales


def reference_tile(a, words, zeros, scales, group, r0, r1, c0, c1, kp0,
                   kp1, out, out_stride):
    """Mirror of fused_tile: plain k-ascending loop, f32 ops."""
    k = a.shape[1]
    for kp in range(kp0, kp1):
        grp = (kp * PACK) // group
        for i in range(PACK):
            kk = kp * PACK + i
            for r in range(r0, r1):
                av = f32(a[r, kk])
                for c in range(c0, c1):
                    v = (words[kp, c] >> (4 * i)) & 0xF
                    w = f32((f32(v) - f32(zeros[grp, c])) * scales[grp, c])
                    o = (r - r0) * out_stride + (c - c0)
                    out[o] = f32(out[o] + f32(av * w))


class PackedLinear:
    """Mirror of exec/layout.rs: tile-major panels + unpacked meta."""

    def __init__(self, words, zeros, scales, block_n):
        kp_total, n = words.shape
        groups = zeros.shape[0]
        bn = max(1, min(block_n, max(n, 1)))
        self.block_n = bn
        self.n = n
        self.words = np.zeros(kp_total * n, dtype=np.int64)
        self.scales = np.zeros(groups * n, dtype=f32)
        self.zeros = np.zeros(groups * n, dtype=f32)
        self.kp_total, self.groups = kp_total, groups
        panels = (n + bn - 1) // bn
        for p in range(panels):
            c0 = p * bn
            w = min((p + 1) * bn, n) - c0
            base = kp_total * c0          # closed-form offset (Rust)
            for kp in range(kp_total):
                for j in range(w):
                    self.words[base + kp * w + j] = words[kp, c0 + j]
            mbase = groups * c0
            for g in range(groups):
                for j in range(w):
                    self.scales[mbase + g * w + j] = scales[g, c0 + j]
                    self.zeros[mbase + g * w + j] = f32(zeros[g, c0 + j])

    def panel_width(self, p):
        return min((p + 1) * self.block_n, self.n) - p * self.block_n

    def panel_words(self, p):
        start = self.kp_total * p * self.block_n
        return self.words[start:start + self.kp_total * self.panel_width(p)]

    def panel_meta(self, p):
        start = self.groups * p * self.block_n
        end = start + self.groups * self.panel_width(p)
        return self.scales[start:end], self.zeros[start:end]


MR = 4
LANE_SPAN = 16
FLAT_SEGMENT_COLS = 64  # flat spans segment at 64 cols (4 KiB LUT cap)


def kernel_tile(a, words, zeros, scales, group, r0, r1, c0, c1, kp0, kp1,
                kp_chunk, out, out_stride, pack=None):
    """Mirror of microkernel.rs::kernel_tile (flat or prepacked)."""
    if r0 >= r1 or c0 >= c1 or kp0 >= kp1:
        return
    k = a.shape[1]
    gp = group // PACK
    chunk = max(kp_chunk, 1)

    def segment_sweep(row_of, lut_of, s0, s1):
        bw = s1 - s0
        col_off = s0 - c0
        lut = np.zeros(bw * 16, dtype=f32)
        wrow = np.zeros(bw, dtype=f32)
        kp = kp0
        cur_grp = -1
        while kp < kp1:
            grp = kp // gp
            if grp != cur_grp:
                for t in range(bw):
                    z, s = lut_of(grp, t)
                    for v in range(16):
                        lut[t * 16 + v] = f32((f32(v) - z) * s)
                cur_grp = grp
            run_end = min(kp1, (grp + 1) * gp, kp + chunk)
            run_span(row_of, lut, wrow, kp, run_end, bw, col_off)
            kp = run_end

    def run_span(row_of, lut, wrow, kpa, kpb, bw, col_off):
        j = 0
        while j + LANE_SPAN <= bw:                      # vector path
            r = r0
            while r < r1:
                mr = min(MR, r1 - r)
                run_tile(row_of, lut, kpa, kpb, r, mr, j, col_off)
                r += mr
            j += LANE_SPAN
        if j < bw:                                       # scalar tail
            for kp in range(kpa, kpb):
                row = row_of(kp)
                for i in range(PACK):
                    for t in range(j, bw):
                        v = (row[t] >> (4 * i)) & 0xF
                        wrow[t] = lut[t * 16 + v]
                    kk = kp * PACK + i
                    for r in range(r0, r1):
                        av = f32(a[r, kk])
                        o = (r - r0) * out_stride + col_off
                        for t in range(j, bw):
                            out[o + t] = f32(out[o + t] + f32(av * wrow[t]))

    def run_tile(row_of, lut, kpa, kpb, r_abs, mr, j, col_off):
        # Accumulators live in locals for the whole run (register tile).
        acc = np.zeros((mr, LANE_SPAN), dtype=f32)
        for r in range(mr):
            o = (r_abs + r - r0) * out_stride + col_off + j
            acc[r] = out[o:o + LANE_SPAN]
        for kp in range(kpa, kpb):
            row = row_of(kp)
            for i in range(PACK):
                wvec = np.zeros(LANE_SPAN, dtype=f32)
                for t in range(LANE_SPAN):
                    v = (row[j + t] >> (4 * i)) & 0xF
                    wvec[t] = lut[(j + t) * 16 + v]
                kk = kp * PACK + i
                for r in range(mr):
                    av = f32(a[r_abs + r, kk])
                    for t in range(LANE_SPAN):
                        acc[r, t] = f32(acc[r, t] + f32(av * wvec[t]))
        for r in range(mr):
            o = (r_abs + r - r0) * out_stride + col_off + j
            out[o:o + LANE_SPAN] = acc[r]

    if pack is None:
        s0 = c0
        while s0 < c1:
            s1 = min(s0 + FLAT_SEGMENT_COLS, c1)

            def row_of(kp, s0=s0, s1=s1):
                return words[kp, s0:s1]

            def lut_of(grp, t, s0=s0):
                return f32(zeros[grp, s0 + t]), scales[grp, s0 + t]

            segment_sweep(row_of, lut_of, s0, s1)
            s0 = s1
    else:
        bn = pack.block_n
        s0 = c0
        while s0 < c1:
            p = s0 // bn
            pc0 = p * bn
            s1 = min(pc0 + bn, c1)
            w = pack.panel_width(p)
            pwords = pack.panel_words(p)
            pscales, pzeros = pack.panel_meta(p)
            j0 = s0 - pc0

            def row_of(kp, pw=pwords, w=w, j0=j0, j1=s1 - pc0):
                return pw[kp * w + j0:kp * w + j1]

            def lut_of(grp, t, ps=pscales, pz=pzeros, w=w, j0=j0):
                return pz[grp * w + j0 + t], ps[grp * w + j0 + t]

            segment_sweep(row_of, lut_of, s0, s1)
            s0 = s1


@pytest.mark.parametrize("seed", range(6))
def test_lut_kernel_bit_identical_to_reference(seed):
    """Flat LUT kernel == reference loop, bit for bit, ragged grid."""
    rng = np.random.default_rng(seed)
    group = int(rng.choice([8, 16, 24, 32]))
    k = group * int(rng.integers(1, 5))
    n = int(rng.integers(1, 11)) * 8
    m = int(rng.integers(1, 12))
    nib, words, zeros, scales = quantize(rng, k, n, group)
    a = rng.uniform(-1, 1, size=(m, k)).astype(f32)
    a[rng.random(size=a.shape) < 0.1] = 0.0  # exact-zero activations
    kp_total = k // PACK

    for _ in range(4):
        r0 = int(rng.integers(0, m))
        r1 = int(rng.integers(r0 + 1, m + 1))
        c0 = int(rng.integers(0, n))
        c1 = int(rng.integers(c0 + 1, n + 1))
        kp0 = int(rng.integers(0, kp_total))
        kp1 = int(rng.integers(kp0 + 1, kp_total + 1))
        chunk = int(rng.choice([1, 2, 3, 8, 1000]))
        stride = c1 - c0 + int(rng.integers(0, 3))
        seed_out = (rng.integers(0, 5, size=(r1 - r0) * stride)
                    .astype(f32) * f32(0.25))

        want = seed_out.copy()
        reference_tile(a, words, zeros, scales, group, r0, r1, c0, c1,
                       kp0, kp1, want, stride)
        got = seed_out.copy()
        kernel_tile(a, words, zeros, scales, group, r0, r1, c0, c1, kp0,
                    kp1, chunk, got, stride)
        assert want.tobytes() == got.tobytes(), (
            f"flat mismatch r{r0}:{r1} c{c0}:{c1} kp{kp0}:{kp1} "
            f"chunk={chunk}")


@pytest.mark.parametrize("seed", range(6))
def test_prepacked_kernel_bit_identical_to_flat(seed):
    """Prepacked traversal == flat, bit for bit, any panel width."""
    rng = np.random.default_rng(100 + seed)
    group = int(rng.choice([8, 16, 32]))
    k = group * int(rng.integers(1, 4))
    n = int(rng.integers(1, 9)) * 8
    m = int(rng.integers(1, 7))
    nib, words, zeros, scales = quantize(rng, k, n, group)
    a = rng.uniform(-1, 1, size=(m, k)).astype(f32)
    kp_total = k // PACK

    for bn in [1, 5, 8, 16, 64]:
        pack = PackedLinear(words, zeros, scales, bn)
        # Panel round-trip: every word/scale/zero must survive exactly.
        for p in range((n + pack.block_n - 1) // pack.block_n):
            c0 = p * pack.block_n
            w = pack.panel_width(p)
            pw = pack.panel_words(p)
            ps, pz = pack.panel_meta(p)
            for kp in range(kp_total):
                for j in range(w):
                    assert pw[kp * w + j] == words[kp, c0 + j]
            for g in range(zeros.shape[0]):
                for j in range(w):
                    assert ps[g * w + j] == scales[g, c0 + j]
                    assert pz[g * w + j] == f32(zeros[g, c0 + j])

        c0 = int(rng.integers(0, n))
        c1 = int(rng.integers(c0 + 1, n + 1))
        chunk = int(rng.choice([1, 4, 1000]))
        flat = np.zeros(m * (c1 - c0), dtype=f32)
        kernel_tile(a, words, zeros, scales, group, 0, m, c0, c1, 0,
                    kp_total, chunk, flat, c1 - c0)
        packed = np.zeros(m * (c1 - c0), dtype=f32)
        kernel_tile(a, words, zeros, scales, group, 0, m, c0, c1, 0,
                    kp_total, chunk, packed, c1 - c0, pack=pack)
        assert flat.tobytes() == packed.tobytes(), f"bn={bn} c{c0}:{c1}"


def test_k_ranges_compose_bitwise():
    """Two k-ranges layered into one window == one full pass (the SplitK
    slice-partial property the executors rely on)."""
    rng = np.random.default_rng(7)
    group, k, n, m = 16, 64, 24, 3
    nib, words, zeros, scales = quantize(rng, k, n, group)
    a = rng.uniform(-1, 1, size=(m, k)).astype(f32)
    full = np.zeros(m * n, dtype=f32)
    kernel_tile(a, words, zeros, scales, group, 0, m, 0, n, 0, 8, 3,
                full, n)
    split = np.zeros(m * n, dtype=f32)
    kernel_tile(a, words, zeros, scales, group, 0, m, 0, n, 0, 3, 3,
                split, n)
    kernel_tile(a, words, zeros, scales, group, 0, m, 0, n, 3, 8, 3,
                split, n)
    assert full.tobytes() == split.tobytes()
