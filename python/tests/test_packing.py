"""S1 tests: GPTQ-style int4 packing + quantization round-trips."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import quant


class TestPackRows:
    def test_roundtrip_small(self):
        rng = np.random.default_rng(0)
        q = rng.integers(0, 16, size=(16, 8), dtype=np.uint8)
        packed = quant.pack_along_rows(q)
        assert packed.shape == (2, 8)
        assert packed.dtype == np.int32
        np.testing.assert_array_equal(quant.unpack_along_rows(packed), q)

    def test_nibble_order(self):
        # Row r*8+i lands in bits 4i..4i+3 — the GPTQ layout the kernel
        # unpacks with (x >> 4*i) & 0xF.
        q = np.zeros((8, 1), dtype=np.uint8)
        q[3, 0] = 0xA
        packed = quant.pack_along_rows(q)
        assert (int(packed[0, 0].view(np.uint32) if hasattr(packed[0, 0], 'view') else np.uint32(packed[0, 0])) >> 12) & 0xF == 0xA

    def test_high_nibble_sign_bit(self):
        # Nibble 7 >= 8 sets the int32 sign bit; unpack must still mask.
        q = np.full((8, 4), 15, dtype=np.uint8)
        packed = quant.pack_along_rows(q)
        assert (packed < 0).all()  # 0xFFFFFFFF as int32
        np.testing.assert_array_equal(quant.unpack_along_rows(packed), q)

    def test_rejects_bad_k(self):
        with pytest.raises(ValueError):
            quant.pack_along_rows(np.zeros((7, 4), dtype=np.uint8))

    def test_rejects_out_of_range(self):
        with pytest.raises(ValueError):
            quant.pack_along_rows(np.full((8, 4), 16, dtype=np.int32))

    @settings(max_examples=30, deadline=None)
    @given(kp=st.integers(1, 16), n=st.integers(1, 64),
           seed=st.integers(0, 2**31 - 1))
    def test_roundtrip_hypothesis(self, kp, n, seed):
        rng = np.random.default_rng(seed)
        q = rng.integers(0, 16, size=(kp * 8, n), dtype=np.uint8)
        np.testing.assert_array_equal(
            quant.unpack_along_rows(quant.pack_along_rows(q)), q)


class TestPackCols:
    def test_roundtrip_small(self):
        rng = np.random.default_rng(1)
        z = rng.integers(0, 16, size=(4, 32), dtype=np.uint8)
        packed = quant.pack_along_cols(z)
        assert packed.shape == (4, 4)
        np.testing.assert_array_equal(quant.unpack_along_cols(packed), z)

    def test_rejects_bad_n(self):
        with pytest.raises(ValueError):
            quant.pack_along_cols(np.zeros((4, 12), dtype=np.uint8))

    @settings(max_examples=30, deadline=None)
    @given(g=st.integers(1, 8), npk=st.integers(1, 16),
           seed=st.integers(0, 2**31 - 1))
    def test_roundtrip_hypothesis(self, g, npk, seed):
        rng = np.random.default_rng(seed)
        z = rng.integers(0, 16, size=(g, npk * 8), dtype=np.uint8)
        np.testing.assert_array_equal(
            quant.unpack_along_cols(quant.pack_along_cols(z)), z)


class TestQuantize:
    def test_shapes(self):
        rng = np.random.default_rng(2)
        w = rng.standard_normal((256, 64), dtype=np.float32)
        qw, s, qz = quant.quantize_weight(w, group_size=64)
        assert qw.shape == (32, 64) and qw.dtype == np.int32
        assert s.shape == (4, 64) and s.dtype == np.float32
        assert qz.shape == (4, 8) and qz.dtype == np.int32

    def test_dequant_error_bound(self):
        # Asymmetric int4: |w - dq(q(w))| <= scale/2 per element.
        rng = np.random.default_rng(3)
        w = rng.standard_normal((128, 32), dtype=np.float32)
        qw, s, qz = quant.quantize_weight(w, group_size=32)
        wd = quant.dequantize(qw, s, qz, group_size=32)
        err = np.abs(wd - w)
        bound = np.repeat(s, 32, axis=0) * 0.5 + 1e-6
        assert (err <= bound).all()

    def test_constant_group_exact(self):
        # A constant group quantizes exactly (scale floor keeps it finite).
        w = np.full((64, 8), 0.37, dtype=np.float32)
        qw, s, qz = quant.quantize_weight(w, group_size=64)
        wd = quant.dequantize(qw, s, qz, group_size=64)
        np.testing.assert_allclose(wd, w, atol=1e-5)

    def test_extremes_hit_qmin_qmax(self):
        w = np.tile(np.linspace(-1, 1, 64, dtype=np.float32).reshape(64, 1),
                    (1, 8))
        qw, s, qz = quant.quantize_weight(w, group_size=64)
        q = quant.unpack_along_rows(qw)
        # fp rounding at the half-step boundary may cost one level.
        assert q.min() <= 1 and q.max() >= 14

    def test_rejects_bad_group(self):
        with pytest.raises(ValueError):
            quant.quantize_weight(np.zeros((100, 8), np.float32), group_size=64)

    @settings(max_examples=20, deadline=None)
    @given(groups=st.integers(1, 4), n=st.sampled_from([8, 16, 32]),
           group_size=st.sampled_from([8, 16, 32, 64]),
           seed=st.integers(0, 2**31 - 1))
    def test_error_bound_hypothesis(self, groups, n, group_size, seed):
        rng = np.random.default_rng(seed)
        k = groups * group_size
        w = rng.standard_normal((k, n), dtype=np.float32)
        qw, s, qz = quant.quantize_weight(w, group_size)
        wd = quant.dequantize(qw, s, qz, group_size)
        bound = np.repeat(s, group_size, axis=0) * 0.5 + 1e-5
        assert (np.abs(wd - w) <= bound).all()
