"""L1 core correctness: Pallas fused kernels vs the pure-jnp oracle.

Every variant (SplitK strided/contiguous, DP) must match ``ref.py`` to f32
tolerance across shapes, block configs, split factors, group sizes and
dtypes — this is the signal that the fused dequant + decomposition is
numerically faithful to the paper's kernel.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import quant
from compile.kernels import (KernelConfig, ref, w4a16_gemm_dp,
                             w4a16_gemm_splitk)


def make_case(m, n, k, group_size, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    qw, s, qz, _ = quant.random_quantized_weight(rng, k, n, group_size)
    a = jnp.asarray(rng.standard_normal((m, k), dtype=np.float32)).astype(dtype)
    return a, jnp.asarray(qw), jnp.asarray(s), jnp.asarray(qz)


def check(fn, config, m=4, n=128, k=256, group_size=64, seed=0,
          dtype=jnp.float32, atol=2e-5):
    a, qw, s, qz = make_case(m, n, k, group_size, seed, dtype)
    want = ref.w4a16_gemm_ref(a, qw, s, qz, group_size)
    got = fn(a, qw, s, qz, group_size=group_size, config=config,
             out_dtype=dtype)
    assert got.shape == want.shape
    assert got.dtype == want.dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=atol, rtol=1e-4)


class TestRefOracle:
    """The oracle itself vs numpy — guards the guard."""

    def test_dequant_matches_numpy(self):
        rng = np.random.default_rng(7)
        qw, s, qz, wd_np = quant.random_quantized_weight(rng, 256, 64, 64)
        wd = ref.dequantize(jnp.asarray(qw), jnp.asarray(s), jnp.asarray(qz), 64)
        np.testing.assert_allclose(np.asarray(wd), wd_np, atol=1e-6)

    def test_gemm_matches_numpy(self):
        rng = np.random.default_rng(8)
        qw, s, qz, wd_np = quant.random_quantized_weight(rng, 128, 64, 32)
        a = rng.standard_normal((3, 128), dtype=np.float32)
        want = a @ wd_np
        got = ref.w4a16_gemm_ref(jnp.asarray(a), jnp.asarray(qw),
                                 jnp.asarray(s), jnp.asarray(qz), 32)
        np.testing.assert_allclose(np.asarray(got), want, atol=1e-4, rtol=1e-4)

    def test_unpack_rows_matches_numpy(self):
        rng = np.random.default_rng(9)
        q = rng.integers(0, 16, size=(64, 24), dtype=np.uint8)
        packed = quant.pack_along_rows(q)
        got = ref.unpack_rows(jnp.asarray(packed))
        np.testing.assert_array_equal(np.asarray(got), q)

    def test_unpack_cols_matches_numpy(self):
        rng = np.random.default_rng(10)
        z = rng.integers(0, 16, size=(4, 64), dtype=np.uint8)
        packed = quant.pack_along_cols(z)
        got = ref.unpack_cols(jnp.asarray(packed))
        np.testing.assert_array_equal(np.asarray(got), z)


class TestSplitK:
    @pytest.mark.parametrize("split_k", [1, 2, 4, 8])
    def test_split_factors(self, split_k):
        check(w4a16_gemm_splitk,
              KernelConfig(block_m=4, block_n=64, block_k=32, split_k=split_k))

    @pytest.mark.parametrize("ordering", ["strided", "contiguous"])
    def test_orderings(self, ordering):
        check(w4a16_gemm_splitk,
              KernelConfig(block_m=4, block_n=32, block_k=32, split_k=4,
                           ordering=ordering))

    @pytest.mark.parametrize("m", [1, 2, 16])
    def test_paper_batch_range(self, m):
        # The paper's regime: m = batch in 1..16.
        check(w4a16_gemm_splitk,
              KernelConfig(block_m=m, block_n=64, block_k=64, split_k=4),
              m=m, n=256, k=512, group_size=128)

    @pytest.mark.parametrize("group_size", [32, 64, 128, 256])
    def test_group_sizes(self, group_size):
        check(w4a16_gemm_splitk,
              KernelConfig(block_m=2, block_n=64, block_k=32, split_k=2),
              m=2, n=128, k=256, group_size=group_size)

    def test_block_m_larger_than_m(self):
        # block_m is clamped to m (the m=1 decode case).
        check(w4a16_gemm_splitk,
              KernelConfig(block_m=16, block_n=64, block_k=32, split_k=4),
              m=1)

    def test_square_llama_shape(self):
        check(w4a16_gemm_splitk,
              KernelConfig(block_m=16, block_n=64, block_k=64, split_k=4),
              m=16, n=512, k=512, group_size=128)

    def test_bf16_activations(self):
        check(w4a16_gemm_splitk,
              KernelConfig(block_m=4, block_n=64, block_k=32, split_k=4),
              dtype=jnp.bfloat16, atol=0.15)

    def test_rejects_indivisible_k(self):
        a, qw, s, qz = make_case(4, 128, 256, 64)
        with pytest.raises(ValueError):
            w4a16_gemm_splitk(a, qw, s, qz, group_size=64,
                              config=KernelConfig(block_m=4, block_n=64,
                                                  block_k=64, split_k=8))

    def test_rejects_block_k_over_group(self):
        a, qw, s, qz = make_case(4, 128, 256, 32)
        with pytest.raises(ValueError):
            w4a16_gemm_splitk(a, qw, s, qz, group_size=32,
                              config=KernelConfig(block_m=4, block_n=64,
                                                  block_k=64, split_k=2))

    def test_rejects_bad_ordering(self):
        a, qw, s, qz = make_case(4, 128, 256, 64)
        with pytest.raises(ValueError):
            w4a16_gemm_splitk(a, qw, s, qz, group_size=64,
                              config=KernelConfig(ordering="zigzag"))

    def test_jit_compatible(self):
        a, qw, s, qz = make_case(4, 128, 256, 64)
        cfg = KernelConfig(block_m=4, block_n=64, block_k=32, split_k=4)
        f = jax.jit(lambda *xs: w4a16_gemm_splitk(
            *xs, group_size=64, config=cfg))
        want = ref.w4a16_gemm_ref(a, qw, s, qz, 64)
        np.testing.assert_allclose(np.asarray(f(a, qw, s, qz)),
                                   np.asarray(want), atol=2e-5, rtol=1e-4)

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.sampled_from([1, 2, 3, 8, 16]),
        n_blocks=st.integers(1, 4),
        k_cfg=st.sampled_from([(32, 2, 2), (32, 4, 2), (64, 2, 4), (64, 4, 1)]),
        ordering=st.sampled_from(["strided", "contiguous"]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, m, n_blocks, k_cfg, ordering, seed):
        block_k, split_k, inner = k_cfg
        k = block_k * split_k * inner
        group_size = k if k <= 256 else block_k
        # group_size must be a multiple of block_k and divide k.
        group_size = block_k * max(1, group_size // block_k)
        while k % group_size:
            group_size //= 2
        n = 64 * n_blocks
        check(w4a16_gemm_splitk,
              KernelConfig(block_m=m, block_n=64, block_k=block_k,
                           split_k=split_k, ordering=ordering),
              m=m, n=n, k=k, group_size=group_size, seed=seed)


class TestDataParallel:
    @pytest.mark.parametrize("m", [1, 4, 16])
    def test_batch_range(self, m):
        check(w4a16_gemm_dp,
              KernelConfig(block_m=m, block_n=64, block_k=64),
              m=m, n=256, k=512, group_size=128)

    @pytest.mark.parametrize("block_k", [8, 16, 32, 64])
    def test_block_k_sweep(self, block_k):
        check(w4a16_gemm_dp,
              KernelConfig(block_m=4, block_n=64, block_k=block_k),
              group_size=64)

    def test_matches_splitk(self):
        # Both decompositions compute the same C (different summation order).
        a, qw, s, qz = make_case(8, 128, 512, 128, seed=11)
        cfg = KernelConfig(block_m=8, block_n=64, block_k=64, split_k=4)
        sk = w4a16_gemm_splitk(a, qw, s, qz, group_size=128, config=cfg)
        dp = w4a16_gemm_dp(a, qw, s, qz, group_size=128, config=cfg)
        np.testing.assert_allclose(np.asarray(sk), np.asarray(dp),
                                   atol=2e-5, rtol=1e-5)

    def test_bf16(self):
        check(w4a16_gemm_dp, KernelConfig(block_m=4, block_n=64, block_k=32),
              dtype=jnp.bfloat16, atol=0.15)

    @settings(max_examples=15, deadline=None)
    @given(m=st.sampled_from([1, 5, 16]), n_blocks=st.integers(1, 3),
           k_blocks=st.integers(1, 4), seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_sweep(self, m, n_blocks, k_blocks, seed):
        k = 64 * k_blocks
        check(w4a16_gemm_dp,
              KernelConfig(block_m=m, block_n=64, block_k=64),
              m=m, n=64 * n_blocks, k=k, group_size=64, seed=seed)
