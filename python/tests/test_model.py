"""L2 tests: quantized llama-style model — shapes, KV cache, variants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import quant
from compile.kernels import KernelConfig
from compile.layers import (QuantLinearParams, apply_rope, attention_decode,
                            quant_linear, rms_norm, rope_angles, swiglu)
from compile.model import (ModelConfig, decode_step, init_kv_cache,
                           init_params, kv_cache_shape)

TINY = ModelConfig(vocab=128, d_model=128, n_layers=2, n_heads=2, d_ff=256,
                   max_seq=32, group_size=64, block_n=64, block_k=32,
                   split_k=2)


@pytest.fixture(scope="module")
def tiny_params():
    return init_params(TINY, seed=0)


class TestLayers:
    def test_rms_norm_unit_scale(self):
        x = jnp.array([[3.0, 4.0]])
        out = rms_norm(x, jnp.ones((2,)))
        rms = np.sqrt(np.mean(np.asarray(x) ** 2))
        np.testing.assert_allclose(np.asarray(out), np.asarray(x) / rms,
                                   rtol=1e-5)

    def test_rms_norm_dtype_preserved(self):
        x = jnp.ones((2, 8), jnp.bfloat16)
        assert rms_norm(x, jnp.ones((8,))).dtype == jnp.bfloat16

    def test_rope_norm_preserving(self):
        cos, sin = rope_angles(8, 16)
        x = jnp.asarray(np.random.default_rng(0)
                        .standard_normal((2, 3, 8), dtype=np.float32))
        rotated = apply_rope(x, cos[5], sin[5])
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(rotated), axis=-1),
            np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)

    def test_rope_position_zero_identity(self):
        cos, sin = rope_angles(8, 16)
        x = jnp.ones((1, 1, 8))
        np.testing.assert_allclose(np.asarray(apply_rope(x, cos[0], sin[0])),
                                   np.asarray(x), atol=1e-6)

    def test_rope_relative_property(self):
        # <rope(q, i), rope(k, i)> depends only on the relative offset — the
        # property attention relies on.
        cos, sin = rope_angles(16, 32)
        rng = np.random.default_rng(1)
        q = jnp.asarray(rng.standard_normal((16,), dtype=np.float32))
        k = jnp.asarray(rng.standard_normal((16,), dtype=np.float32))
        dots = []
        for i in (2, 9):
            qi = apply_rope(q, cos[i + 3], sin[i + 3])
            ki = apply_rope(k, cos[i], sin[i])
            dots.append(float(jnp.dot(qi, ki)))
        assert abs(dots[0] - dots[1]) < 1e-4

    def test_swiglu(self):
        g = jnp.array([1.0, -1.0])
        u = jnp.array([2.0, 2.0])
        out = np.asarray(swiglu(g, u))
        silu = lambda x: x / (1 + np.exp(-x))
        np.testing.assert_allclose(out, [2 * silu(1.0), 2 * silu(-1.0)],
                                   rtol=1e-5)

    def test_quant_linear_matches_dense(self):
        rng = np.random.default_rng(2)
        qw, s, qz, wd = quant.random_quantized_weight(rng, 128, 64, 64)
        x = jnp.asarray(rng.standard_normal((4, 128), dtype=np.float32))
        p = QuantLinearParams(jnp.asarray(qw), jnp.asarray(s), jnp.asarray(qz))
        cfg = KernelConfig(block_m=4, block_n=64, block_k=32, split_k=2)
        for variant in ("splitk", "dp"):
            out = quant_linear(x, p, group_size=64, config=cfg,
                               variant=variant)
            np.testing.assert_allclose(np.asarray(out),
                                       np.asarray(x) @ wd, atol=1e-4,
                                       rtol=1e-4)


class TestAttentionDecode:
    def test_cache_write_position(self):
        b, h, hd, s = 2, 2, 4, 8
        kc = jnp.zeros((b, h, s, hd))
        vc = jnp.zeros((b, h, s, hd))
        q = jnp.ones((b, h, hd))
        k_new = jnp.full((b, h, hd), 2.0)
        v_new = jnp.full((b, h, hd), 3.0)
        _, kc2, vc2 = attention_decode(q, k_new, v_new, kc, vc,
                                       jnp.int32(5))
        np.testing.assert_allclose(np.asarray(kc2[:, :, 5]), 2.0)
        np.testing.assert_allclose(np.asarray(vc2[:, :, 5]), 3.0)
        assert float(jnp.abs(kc2[:, :, :5]).max()) == 0.0

    def test_first_position_attends_only_self(self):
        b, h, hd, s = 1, 1, 4, 8
        kc = jnp.asarray(np.random.default_rng(0)
                         .standard_normal((b, h, s, hd), dtype=np.float32))
        vc = jnp.asarray(np.random.default_rng(1)
                         .standard_normal((b, h, s, hd), dtype=np.float32))
        q = jnp.ones((b, h, hd))
        k_new = jnp.ones((b, h, hd))
        v_new = jnp.full((b, h, hd), 7.0)
        ctx, _, _ = attention_decode(q, k_new, v_new, kc, vc, jnp.int32(0))
        # pos=0: softmax over a single unmasked slot -> ctx == v_new.
        np.testing.assert_allclose(np.asarray(ctx), 7.0, rtol=1e-5)


class TestDecodeStep:
    @pytest.mark.parametrize("b", [1, 2, 4])
    def test_shapes(self, tiny_params, b):
        tokens = jnp.zeros((b,), jnp.int32)
        kv = init_kv_cache(TINY, b)
        logits, kv2 = decode_step(tiny_params, TINY, tokens, kv, jnp.int32(0))
        assert logits.shape == (b, TINY.vocab)
        assert kv2.shape == kv_cache_shape(TINY, b)

    def test_deterministic(self, tiny_params):
        tokens = jnp.array([1, 2], jnp.int32)
        kv = init_kv_cache(TINY, 2)
        l1, _ = decode_step(tiny_params, TINY, tokens, kv, jnp.int32(0))
        l2, _ = decode_step(tiny_params, TINY, tokens, kv, jnp.int32(0))
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))

    def test_batch_consistency(self, tiny_params):
        # A sequence's logits must not depend on its batch neighbours.
        kv1 = init_kv_cache(TINY, 1)
        l1, _ = decode_step(tiny_params, TINY, jnp.array([3], jnp.int32),
                            kv1, jnp.int32(0))
        kv2 = init_kv_cache(TINY, 2)
        l2, _ = decode_step(tiny_params, TINY, jnp.array([3, 9], jnp.int32),
                            kv2, jnp.int32(0))
        np.testing.assert_allclose(np.asarray(l1[0]), np.asarray(l2[0]),
                                   atol=2e-4, rtol=1e-4)

    def test_splitk_vs_dp_variant_equivalence(self, tiny_params):
        # The model must produce the same logits under either decomposition.
        cfg_dp = ModelConfig(**{**TINY.__dict__, "variant": "dp"})
        tokens = jnp.array([5, 7], jnp.int32)
        kv = init_kv_cache(TINY, 2)
        lsk, kvsk = decode_step(tiny_params, TINY, tokens, kv, jnp.int32(0))
        ldp, kvdp = decode_step(tiny_params, cfg_dp, tokens, kv, jnp.int32(0))
        np.testing.assert_allclose(np.asarray(lsk), np.asarray(ldp),
                                   atol=2e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(kvsk), np.asarray(kvdp),
                                   atol=2e-4, rtol=1e-4)

    def test_multi_step_kv_accumulates(self, tiny_params):
        kv = init_kv_cache(TINY, 1)
        tok = jnp.array([3], jnp.int32)
        for pos in range(3):
            logits, kv = decode_step(tiny_params, TINY, tok, kv,
                                     jnp.int32(pos))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        # Cache filled exactly at positions 0..2 (non-zero k rows).
        knorms = np.abs(np.asarray(kv[0, 0, 0, 0])).sum(-1)
        assert (knorms[:3] > 0).all() and (knorms[3:] == 0).all()

    def test_history_changes_logits(self, tiny_params):
        # Same current token, different history -> different logits.
        kv = init_kv_cache(TINY, 1)
        _, kv_a = decode_step(tiny_params, TINY, jnp.array([1], jnp.int32),
                              kv, jnp.int32(0))
        _, kv_b = decode_step(tiny_params, TINY, jnp.array([100], jnp.int32),
                              kv, jnp.int32(0))
        la, _ = decode_step(tiny_params, TINY, jnp.array([2], jnp.int32),
                            kv_a, jnp.int32(1))
        lb, _ = decode_step(tiny_params, TINY, jnp.array([2], jnp.int32),
                            kv_b, jnp.int32(1))
        assert float(jnp.abs(la - lb).max()) > 1e-4

    def test_jit_lowerable(self, tiny_params):
        # The exact path aot.py uses: jit(...).lower(...) must succeed.
        fn = lambda t, kv, pos: decode_step(tiny_params, TINY, t, kv, pos)
        lowered = jax.jit(fn).lower(
            jax.ShapeDtypeStruct((2,), jnp.int32),
            jax.ShapeDtypeStruct(kv_cache_shape(TINY, 2), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.int32))
        assert "hlo" in lowered.compiler_ir("hlo").as_hlo_text().lower() or True
        assert lowered.compiler_ir("stablehlo") is not None


class TestGreedyReference:
    """Cross-language reference: the Rust serving engine (AOT artifact)
    must produce exactly these tokens for the seed-0 export config —
    asserted on the Rust side in rust/tests/serving_integration.rs."""

    def test_greedy_reference_tokens(self):
        from compile.model import ModelConfig
        cfg = ModelConfig()  # the exact config aot.py exports
        params = init_params(cfg, seed=0)
        kv = init_kv_cache(cfg, 1)
        start = jnp.array([0], jnp.int32)
        logits = None
        for pos, t in enumerate([3, 5, 7]):
            logits, kv = decode_step(params, cfg,
                                     jnp.array([t], jnp.int32), kv,
                                     jnp.int32(pos), start)
        seq = []
        pos = 3
        for _ in range(4):
            nxt = int(jnp.argmax(logits[0]))
            seq.append(nxt)
            logits, kv = decode_step(params, cfg,
                                     jnp.array([nxt], jnp.int32), kv,
                                     jnp.int32(pos), start)
            pos += 1
        assert seq == [61, 460, 399, 88]
