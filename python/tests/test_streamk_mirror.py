"""Python mirror of the Rust StreamK host executor's index math and
accumulation semantics (`rust/src/kernels/exec/streamk.rs`), plus the
batcher flush policy (`rust/src/coordinator/batcher.rs`).

The Rust growth environment has no cargo toolchain, so — as with the
PR 1 kernel-index mirror — the span-partition logic is cross-validated
here against exhaustive invariants and a float reference. This is
auxiliary evidence next to the Rust unit/property tests, which run
wherever a toolchain exists (CI).

Mirrored contracts:

* the flattened `(n-tile x k-unit)` iteration space is covered exactly
  once by the per-span contribution descriptors (no gaps, no overlap),
  span desc ranges are consecutive, and per tile the k-ranges ascend in
  descriptor order (the merge order == ascending k);
* the worker-assignment loop hands out contiguous span runs that
  exhaust the descriptor list for any thread count;
* float32 ascending-k accumulation per contribution + ascending-span
  merge stays within 1e-4 of a float64 dense reference, collapses to
  the DP order bitwise at one span, and is bit-identical across span
  counts on exactly-representable inputs (the Rust property
  `prop_fused_decompositions_bit_identical_on_exact_inputs`);
* the batcher window flush drains the whole queue (no stranded tail —
  the PR 3 regression).

Run standalone for the full 20k-case partition sweep:
`python tests/test_streamk_mirror.py`
"""

import random

import numpy as np


def ceil_div(a, b):
    return -(-a // b)


def partition(n, kp_total, bn, kp_chunk, workers):
    """Mirror of the span/descriptor construction in streamk.rs."""
    n_tiles = ceil_div(n, bn)
    k_units = ceil_div(kp_total, kp_chunk)
    total = n_tiles * k_units
    spans = max(1, min(workers, total))
    descs, span_ranges = [], []
    for s in range(spans):
        u0, u1 = s * total // spans, (s + 1) * total // spans
        d0 = len(descs)
        u = u0
        while u < u1:
            tile = u // k_units
            s0 = u % k_units
            s1 = min(s0 + (u1 - u), k_units)
            descs.append((tile, s0 * kp_chunk, min(s1 * kp_chunk, kp_total)))
            u += s1 - s0
        span_ranges.append((d0, len(descs)))
        assert u1 > u0, "empty span"
    return n_tiles, k_units, descs, span_ranges


def check_partition(n, kp_total, bn, kp_chunk, workers):
    n_tiles, k_units, descs, span_ranges = partition(
        n, kp_total, bn, kp_chunk, workers)
    # Exact coverage, no overlap.
    cover = set()
    for tile, kp0, kp1 in descs:
        assert 0 <= tile < n_tiles
        assert 0 <= kp0 < kp1 <= kp_total
        for kp in range(kp0, kp1):
            assert (tile, kp) not in cover, "overlap"
            cover.add((tile, kp))
    assert len(cover) == n_tiles * kp_total
    # Consecutive, exhaustive span ranges.
    off = 0
    for d0, d1 in span_ranges:
        assert d0 == off and d1 >= d0
        off = d1
    assert off == len(descs)
    # Per-tile k-ranges ascend in desc order (merge order == k order).
    last = {}
    for tile, kp0, kp1 in descs:
        assert last.get(tile, -1) <= kp0
        last[tile] = kp1
    # Worker assignment: contiguous span runs, every desc handed out.
    spans = len(span_ranges)
    for threads in (1, 2, 3, 5, 8, 64):
        w_eff = max(1, min(threads, spans))
        next_span, desc_off = 0, 0
        for w in range(w_eff):
            count = (spans - next_span) // (w_eff - w)
            assert count >= 1
            desc_off = span_ranges[next_span + count - 1][1]
            next_span += count
        assert next_span == spans and desc_off == len(descs)


def test_partition_invariants_random_sweep(cases=4000, seed=7):
    rng = random.Random(seed)
    for _ in range(cases):
        check_partition(
            n=rng.randint(1, 80),
            kp_total=rng.randint(1, 64),
            bn=rng.choice([1, 3, 5, 8, 16, 64, 1000]),
            kp_chunk=rng.choice([1, 3, 4, 8, 32, 1000]),
            workers=rng.randint(1, 40),
        )


# ---- numeric mirror --------------------------------------------------

def _f32_ascending_dot(a_col, w_col):
    """fused_tile inner-loop semantics: f32 acc += a*w, ascending k."""
    acc = np.float32(0.0)
    for av, wv in zip(a_col, w_col):
        acc = np.float32(acc + np.float32(np.float32(av) * np.float32(wv)))
    return acc


def streamk_f32(a, w, bn, kp_chunk, workers):
    m, k = a.shape
    n = w.shape[1]
    _, _, descs, _ = partition(n, k // 8, bn, kp_chunk, workers)
    out = np.zeros((m, n), dtype=np.float32)
    for tile, kp0, kp1 in descs:
        c0, c1 = tile * bn, min((tile + 1) * bn, n)
        contrib = np.zeros((m, c1 - c0), dtype=np.float32)
        for r in range(m):
            for j, c in enumerate(range(c0, c1)):
                contrib[r, j] = _f32_ascending_dot(
                    a[r, 8 * kp0:8 * kp1], w[8 * kp0:8 * kp1, c])
        out[:, c0:c1] = np.float32(out[:, c0:c1] + contrib)
    return out


def dp_f32(a, w):
    m, k = a.shape
    n = w.shape[1]
    out = np.zeros((m, n), dtype=np.float32)
    for r in range(m):
        for c in range(n):
            out[r, c] = _f32_ascending_dot(a[r, :], w[:, c])
    return out


def test_streamk_matches_f64_reference(cases=12, seed=3):
    rnd = random.Random(seed)
    rng = np.random.default_rng(seed)
    for _ in range(cases):
        m, k, n = rnd.randint(1, 4), 8 * rnd.randint(1, 6), rnd.randint(1, 14)
        a = rng.uniform(-1, 1, (m, k)).astype(np.float32)
        w = (rng.standard_normal((k, n)) * 0.1).astype(np.float32)
        ref = a.astype(np.float64) @ w.astype(np.float64)
        for workers in (1, 2, 3, 7, 16):
            got = streamk_f32(a, w, rnd.choice([1, 3, 8, 1000]),
                              rnd.choice([1, 2, 1000]), workers)
            assert np.max(np.abs(got - ref)) <= 1e-4


def test_single_span_is_dp_bitwise(seed=5):
    rng = np.random.default_rng(seed)
    a = rng.uniform(-1, 1, (3, 32)).astype(np.float32)
    w = (rng.standard_normal((32, 12)) * 0.1).astype(np.float32)
    assert streamk_f32(a, w, 1000, 1000, 1).tobytes() == dp_f32(a, w).tobytes()


def test_exact_inputs_bit_identical_across_span_counts(cases=8, seed=11):
    rnd = random.Random(seed)
    rng = np.random.default_rng(seed)
    scales = np.array([0.25, 0.125, 0.0625], dtype=np.float32)
    for _ in range(cases):
        m, k, n = rnd.randint(1, 4), 8 * rnd.randint(1, 5), rnd.randint(1, 10)
        a = rng.integers(-4, 5, (m, k)).astype(np.float32)
        w = (rng.integers(0, 16, (k, n)).astype(np.float32)
             - rng.integers(0, 16, (1, n)).astype(np.float32)) \
            * scales[rng.integers(0, 3, (1, n))]
        base = dp_f32(a, w).tobytes()
        for workers in (2, 3, 5, 8, 13):
            assert streamk_f32(a, w, 4, 2, workers).tobytes() == base


# ---- batcher flush mirror -------------------------------------------

def _covering(buckets, n):
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


def test_batcher_flush_never_strands(cases=2000, seed=1):
    """Mirror of DynamicBatcher::poll: a window flush (queue below the
    largest bucket) must drain the whole queue in one covering-bucket
    batch — the PR 3 regression fix."""
    rnd = random.Random(seed)
    for _ in range(cases):
        buckets = sorted(rnd.sample([1, 2, 4, 8, 16, 32], rnd.randint(1, 6)))
        q = list(range(rnd.randint(1, 80)))
        max_b = buckets[-1]
        while q:
            if len(q) >= max_b:
                take, bucket = max_b, max_b
            else:
                take = min(len(q), max_b)
                bucket = _covering(buckets, take)
            batch, q = q[:take], q[take:]
            assert len(batch) <= bucket and bucket in buckets
            if take < max_b:
                assert not q, "flush stranded a tail"


if __name__ == "__main__":
    test_partition_invariants_random_sweep(cases=20000)
    test_streamk_matches_f64_reference(cases=40)
    test_single_span_is_dp_bitwise()
    test_exact_inputs_bit_identical_across_span_counts(cases=15)
    test_batcher_flush_never_strands()
    print("OK: partition invariants (20k cases), f64-reference tolerance, "
          "DP bit-equality at one span, exact-input bit-identity, "
          "batcher flush drain")
