"""Python mirror of the `splitk lint` static-analysis pass.

Re-implements `rust/src/analysis/{lexer,rules}.rs` line-for-line in
pure Python (stdlib only) and runs the same rules over the same
sources (`rust/src/**/*.rs` + DESIGN.md headings), so the analysis
actually *executes* in environments without a Rust toolchain — the
same cross-validate-without-cross-execution pattern as the sampler /
micro-kernel / StreamK / kvpage mirrors. Any change to the Rust
lexer or rules must land here in the same commit.

Covers (DESIGN.md §10):
  raw-lock       locks in coordinator/ outside coordinator::sync
  unwrap         unannotated unwrap/expect on hot paths
  hash-iter      hash containers in deterministic scopes
  alloc          allocation in kernel executors off scratch/warmup
  wallclock      Instant::now/SystemTime outside timing modules
  panic-message  message-less asserts/panics in pool/ledger code
  design-ref     `§N` citations must resolve to DESIGN.md headings

The repo-tree test at the bottom is the in-container equivalent of
the CI `splitk lint` gate: it must report zero findings.
"""

import re
from pathlib import Path

REPO = Path(__file__).resolve().parents[2]
SRC = REPO / "rust" / "src"
DESIGN = REPO / "DESIGN.md"

# ---------------------------------------------------------------------------
# Lexer (mirror of rust/src/analysis/lexer.rs)
# ---------------------------------------------------------------------------


def _is_ident(c):
    return c.isalnum() and c.isascii() or c == "_"


def _split_streams(src):
    """Blank comments/string-interiors out of the code stream and
    everything-but-comments out of the comment stream. Both outputs
    align with ``src`` char-for-char (newlines preserved)."""
    n = len(src)
    code = [" "] * n
    com = [" "] * n

    def skip_string(i):
        while i < n:
            if src[i] == "\\":
                i += 2
            elif src[i] == '"':
                code[i] = '"'
                return i + 1
            else:
                if src[i] == "\n":
                    code[i] = "\n"
                i += 1
        return n

    def skip_raw(i, hashes):
        while i < n:
            if src[i] == '"':
                h = 0
                while h < hashes and i + 1 + h < n and src[i + 1 + h] == "#":
                    h += 1
                if h == hashes:
                    code[i] = '"'
                    for k in range(hashes):
                        code[i + 1 + k] = "#"
                    return i + 1 + hashes
            if src[i] == "\n":
                code[i] = "\n"
            i += 1
        return n

    def char_or_lifetime(i):
        code[i] = "'"
        if i + 1 < n and src[i + 1] == "\\":
            j = i + 2
            while j < n and src[j] != "'":
                if src[j] == "\n":
                    code[j] = "\n"
                j += 1
            if j < n:
                code[j] = "'"
                j += 1
            return j
        if i + 2 < n and src[i + 2] == "'" and src[i + 1] != "'":
            code[i + 2] = "'"
            return i + 3
        return i + 1

    def raw_or_byte(i):
        j = i + 1
        raw = src[i] == "r"
        if src[i] == "b" and j < n:
            if src[j] == "'":
                code[i] = "b"
                return char_or_lifetime(j)
            if src[j] == "r":
                raw = True
                j += 1
        if raw:
            hashes = 0
            while j < n and src[j] == "#":
                hashes += 1
                j += 1
            if j < n and src[j] == '"':
                for k in range(i, j):
                    code[k] = src[k]
                code[j] = '"'
                return skip_raw(j + 1, hashes)
            return None
        if j < n and src[j] == '"':
            code[i] = "b"
            code[j] = '"'
            return skip_string(j + 1)
        return None

    i = 0
    while i < n:
        c = src[i]
        if c == "\n":
            code[i] = "\n"
            com[i] = "\n"
            i += 1
        elif c == "/" and i + 1 < n and src[i + 1] == "/":
            while i < n and src[i] != "\n":
                com[i] = src[i]
                i += 1
        elif c == "/" and i + 1 < n and src[i + 1] == "*":
            depth = 1
            com[i] = "/"
            com[i + 1] = "*"
            i += 2
            while i < n and depth > 0:
                if src[i] == "\n":
                    com[i] = "\n"
                    code[i] = "\n"
                    i += 1
                elif src[i] == "/" and i + 1 < n and src[i + 1] == "*":
                    depth += 1
                    com[i] = "/"
                    com[i + 1] = "*"
                    i += 2
                elif src[i] == "*" and i + 1 < n and src[i + 1] == "/":
                    depth -= 1
                    com[i] = "*"
                    com[i + 1] = "/"
                    i += 2
                else:
                    com[i] = src[i]
                    i += 1
        elif c == '"':
            code[i] = '"'
            i = skip_string(i + 1)
        elif c in ("r", "b") and not (i > 0 and _is_ident(src[i - 1])):
            nxt = raw_or_byte(i)
            if nxt is None:
                code[i] = c
                i += 1
            else:
                i = nxt
        elif c == "'":
            i = char_or_lifetime(i)
        else:
            code[i] = c
            i += 1
    return "".join(code), "".join(com)


class Scan:
    def __init__(self, src):
        code, com = _split_streams(src)
        self.code = code.split("\n")
        self.comment = com.split("\n")
        nlines = len(self.code)
        self.in_test = [False] * nlines
        self.fn_of = [None] * nlines
        # Char index -> 0-based line (over the joined code stream).
        line_of = []
        line = 0
        for c in code:
            line_of.append(line)
            if c == "\n":
                line += 1
        if code:
            self._mark_test_regions(code, line_of)
            self._mark_fn_spans(code, line_of)

    def fn_name(self, line):
        return self.fn_of[line]

    def _mark_test_regions(self, code, line_of):
        att = "#[cfg(test)]"
        from_ = 0
        while True:
            p = code.find(att, from_)
            if p < 0:
                return
            q = p + len(att)
            end = len(code)
            while q < len(code):
                if code[q] == ";":
                    end = q + 1
                    break
                if code[q] == "{":
                    depth = 1
                    r = q + 1
                    while r < len(code) and depth > 0:
                        if code[r] == "{":
                            depth += 1
                        elif code[r] == "}":
                            depth -= 1
                        r += 1
                    end = r
                    break
                q += 1
            last = line_of[min(max(end - 1, 0), len(line_of) - 1)]
            for ln in range(line_of[p], last + 1):
                self.in_test[ln] = True
            from_ = max(end, p + 1)

    def _mark_fn_spans(self, code, line_of):
        n = len(code)
        i = 0
        while True:
            p = code.find("fn", i)
            if p < 0:
                return
            i = p + 2
            left_ok = p == 0 or not _is_ident(code[p - 1])
            right_ok = p + 2 >= n or not _is_ident(code[p + 2])
            if not (left_ok and right_ok):
                continue
            j = p + 2
            while j < n and code[j].isspace():
                j += 1
            name_start = j
            while j < n and _is_ident(code[j]):
                j += 1
            if j == name_start:
                continue
            name = code[name_start:j]
            depth = 0
            body = None
            while j < n:
                c = code[j]
                if c == "(":
                    depth += 1
                elif c == ")":
                    depth -= 1
                elif c == "{" and depth == 0:
                    body = j
                    break
                elif c == ";" and depth == 0:
                    break
                j += 1
            if body is None:
                continue
            depth = 1
            r = body + 1
            while r < n and depth > 0:
                if code[r] == "{":
                    depth += 1
                elif code[r] == "}":
                    depth -= 1
                r += 1
            first = line_of[p]
            last = line_of[min(max(r - 1, 0), n - 1)]
            for ln in range(first, last + 1):
                self.fn_of[ln] = name


# ---------------------------------------------------------------------------
# Rules (mirror of rust/src/analysis/rules.rs)
# ---------------------------------------------------------------------------

LOCK_FNS = {"lock_recover", "wait_timeout_recover"}
ALLOC_FNS = {"new", "ensure_tile_scratches", "ensure_stitch_arenas",
             "self_check"}
WALLCLOCK_FILES = {
    "main.rs",
    "util/bench.rs",
    "kernels/autotune.rs",
    "coordinator/router.rs",
    "coordinator/engine.rs",
    "coordinator/batcher.rs",
    "http/proto.rs",
    "http/reactor.rs",
}
PANIC_MSG_FILES = {"coordinator/kvpage.rs", "coordinator/engine.rs"}


def design_sections(text):
    out = set()
    for line in text.splitlines():
        s = line.lstrip()
        if s.startswith("## §"):
            m = re.match(r"\d+", s[len("## §"):])
            if m:
                out.add(int(m.group(0)))
    return out


def _allowed(scan, idx, rule):
    needle = "lint: allow(%s):" % rule

    def has(line):
        p = line.find(needle)
        return p >= 0 and line[p + len(needle):].strip() != ""

    if has(scan.comment[idx]):
        return True
    j = idx
    while j > 0:
        j -= 1
        if scan.code[j].strip() or not scan.comment[j].strip():
            return False
        if has(scan.comment[j]):
            return True
    return False


def _token_rule(out, rel, scan, rule, patterns, in_scope, fn_allow, message):
    if not in_scope:
        return
    for i, code in enumerate(scan.code):
        if scan.in_test[i]:
            continue
        if not any(p in code for p in patterns):
            continue
        if scan.fn_name(i) in fn_allow:
            continue
        if _allowed(scan, i, rule):
            continue
        out.append((rule, rel, i + 1, message))


_MACROS = [
    ("panic!", 0),
    ("debug_assert_eq!", 2),
    ("debug_assert_ne!", 2),
    ("debug_assert!", 1),
    ("assert_eq!", 2),
    ("assert_ne!", 2),
    ("assert!", 1),
]


def _panic_message_rule(out, rel, scan):
    if rel not in PANIC_MSG_FILES:
        return
    full = "\n".join(scan.code)
    line_of = []
    line = 0
    for c in full:
        line_of.append(line)
        if c == "\n":
            line += 1
    i = 0
    n = len(full)
    while i < n:
        hit = None
        for mac, msg_arg in _MACROS:
            if full.startswith(mac, i) and (
                    i == 0 or not _is_ident(full[i - 1])):
                hit = (mac, msg_arg)
                break
        if hit is None:
            i += 1
            continue
        mac, msg_arg = hit
        j = i + len(mac)
        while j < n and full[j].isspace():
            j += 1
        if j >= n or full[j] != "(":
            i += len(mac)
            continue
        depth = 1
        arg = 0
        string_in = [False]
        k = j + 1
        while k < n and depth > 0:
            c = full[k]
            if c in "([{":
                depth += 1
            elif c in ")]}":
                depth -= 1
            elif c == "," and depth == 1:
                arg += 1
                string_in.append(False)
            elif c == '"' and depth == 1:
                string_in[arg] = True
            k += 1
        msg_ok = any(string_in[msg_arg:])
        fline = line_of[min(i, len(line_of) - 1)]
        if (not msg_ok and not scan.in_test[fline]
                and not _allowed(scan, fline, "panic-message")):
            out.append((
                "panic-message", rel, fline + 1,
                "`%s` without a message string — ledger panics must "
                "name the violated invariant" % mac))
        i = max(k, i + len(mac))


def _design_ref_rule(out, rel, scan, sections):
    for i, comment in enumerate(scan.comment):
        for m in re.finditer(r"§(\d+)", comment):
            n = int(m.group(1))
            if n not in sections:
                out.append((
                    "design-ref", rel, i + 1,
                    "comment cites DESIGN.md §%d, which has no "
                    "`## §%d` heading" % (n, n)))


def lint_source(rel, src, sections):
    scan = Scan(src)
    out = []
    in_coordinator = rel.startswith("coordinator/")
    in_exec = rel.startswith("kernels/exec/")
    in_http = rel.startswith("http/")
    _token_rule(
        out, rel, scan, "raw-lock", [".lock()", ".wait_timeout("],
        in_coordinator or in_http, LOCK_FNS,
        "raw lock/wait outside coordinator::sync — use lock_recover / "
        "wait_timeout_recover (poison recovery, PR-6 contract)")
    _token_rule(
        out, rel, scan, "unwrap", [".unwrap()", ".expect("],
        in_coordinator or in_exec or in_http, set(),
        "unannotated unwrap/expect on a hot path — state why it is "
        "infallible with `// lint: allow(unwrap): <reason>` or return "
        "an error")
    _token_rule(
        out, rel, scan, "hash-iter", ["HashMap", "HashSet"],
        rel.startswith("kernels/") or rel.startswith("model/")
        or rel in ("coordinator/engine.rs", "coordinator/router.rs"),
        set(),
        "hash container in a deterministic scope — iteration order is "
        "unstable; use BTreeMap/BTreeSet or annotate why order never "
        "escapes")
    _token_rule(
        out, rel, scan, "alloc",
        ["vec!", "Vec::new", ".collect(", ".to_vec("],
        in_exec, ALLOC_FNS,
        "allocation in a kernel executor off the scratch/warmup paths "
        "(PR-4 allocation-free-after-warmup contract)")
    _token_rule(
        out, rel, scan, "wallclock", ["Instant::now", "SystemTime"],
        rel not in WALLCLOCK_FILES and not rel.startswith("metrics/"),
        set(),
        "wall-clock read outside the bench/autotune/deadline modules "
        "breaks replay determinism")
    _panic_message_rule(out, rel, scan)
    _design_ref_rule(out, rel, scan, sections)
    return out


def run_lint(repo_root=REPO):
    src_root = repo_root / "rust" / "src"
    sections = design_sections((repo_root / "DESIGN.md").read_text())
    findings = []
    for path in sorted(src_root.rglob("*.rs")):
        rel = path.relative_to(src_root).as_posix()
        findings.extend(lint_source(rel, path.read_text(), sections))
    findings.sort(key=lambda f: (f[1], f[2], f[0]))
    return findings


# ---------------------------------------------------------------------------
# Lexer fixtures
# ---------------------------------------------------------------------------


def test_comments_stripped_and_captured():
    s = Scan("let x = 1; // trailing .lock()\n/* block */ let y;\n")
    assert ".lock()" not in s.code[0]
    assert ".lock()" in s.comment[0]
    assert "let y;" in s.code[1]
    assert "block" not in s.code[1]


def test_block_comments_nest():
    s = Scan("/* outer /* inner */ still comment */ let z = 2;\n")
    assert "let z = 2;" in s.code[0]
    assert "still" not in s.code[0]


def test_string_interiors_blank_quotes_survive():
    s = Scan('let m = "do not .unwrap() here";\n')
    assert ".unwrap()" not in s.code[0]
    assert s.code[0].count('"') == 2


def test_raw_strings_and_escapes():
    s = Scan('let a = r#"raw .lock() "quoted" body"#;\n'
             'let b = "esc \\" .expect( more";\n')
    assert ".lock()" not in s.code[0]
    assert ".expect(" not in s.code[1]
    assert s.code[1].rstrip().endswith(";")


def test_lifetimes_vs_char_literals():
    s = Scan("fn f<'a>(x: &'a str) -> &'a str { x }\nlet c = 'x';\n")
    assert "str" in s.code[0]
    assert "x" not in s.code[1].replace("let", "").replace("c", "", 1) \
        .split("=")[-1].replace("'", "").strip().replace(";", "")


def test_cfg_test_region():
    s = Scan("fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\n"
             "fn after() {}\n")
    assert not s.in_test[0]
    assert all(s.in_test[1:5])
    assert not s.in_test[5]


def test_innermost_fn_wins():
    s = Scan("fn outer() {\n    fn inner() {\n        let q = 1;\n    }\n"
             "    let w = 2;\n}\n")
    assert s.fn_name(2) == "inner"
    assert s.fn_name(4) == "outer"
    assert s.fn_name(0) == "outer"


# ---------------------------------------------------------------------------
# Rule fixtures (positive / negative / false-positive)
# ---------------------------------------------------------------------------

SECTIONS = {1, 2}


def rules_of(rel, src):
    return [f[0] for f in lint_source(rel, src, SECTIONS)]


def test_raw_lock_positive_and_scope():
    src = "fn f(m: &Mutex<u32>) { let _ = m.lock(); }\n"
    assert rules_of("coordinator/x.rs", src) == ["raw-lock"]
    # The HTTP front door holds locks too (worker-handle pool) and is
    # held to the same poison-recovery contract.
    assert rules_of("http/server.rs", src) == ["raw-lock"]
    assert rules_of("kernels/x.rs", src) == []


def test_raw_lock_recover_helpers_exempt():
    src = "fn lock_recover(m: &Mutex<u32>) { m.lock(); }\n"
    assert rules_of("coordinator/sync.rs", src) == []
    src2 = ("fn wait_timeout_recover(cv: &Condvar) {\n"
            "    cv.wait_timeout(guard, dur);\n}\n")
    assert rules_of("coordinator/sync.rs", src2) == []


def test_unwrap_annotation_grammar():
    bare = "fn f(x: Option<u32>) { x.unwrap(); }\n"
    assert rules_of("coordinator/x.rs", bare) == ["unwrap"]
    assert rules_of("http/api.rs", bare) == ["unwrap"]
    above = ("fn f(x: Option<u32>) {\n"
             "    // lint: allow(unwrap): set by construction\n"
             "    x.unwrap();\n}\n")
    assert rules_of("coordinator/x.rs", above) == []
    trailing = ("fn f(x: Option<u32>) { x.unwrap(); "
                "// lint: allow(unwrap): set above\n}\n")
    assert rules_of("coordinator/x.rs", trailing) == []
    no_reason = ("fn f(x: Option<u32>) {\n"
                 "    // lint: allow(unwrap):\n    x.unwrap();\n}\n")
    assert rules_of("coordinator/x.rs", no_reason) == ["unwrap"]
    wrong_rule = ("fn f(x: Option<u32>) {\n"
                  "    // lint: allow(alloc): not the right key\n"
                  "    x.unwrap();\n}\n")
    assert rules_of("coordinator/x.rs", wrong_rule) == ["unwrap"]


def test_unwrap_or_else_not_flagged():
    src = "fn f(x: Option<u32>) { x.unwrap_or_else(|| 0); x.unwrap_or(1); }\n"
    assert rules_of("coordinator/x.rs", src) == []


def test_false_positives_strings_comments_tests():
    src = ('fn f() { let m = ".unwrap() .lock()"; }\n'
           "// .unwrap() in a comment\n"
           "#[cfg(test)]\n"
           "mod tests {\n"
           "    fn t(x: Option<u32>) { x.unwrap(); }\n"
           "}\n")
    assert rules_of("coordinator/x.rs", src) == []


def test_hash_iter_scopes():
    src = "fn f() { let m: HashMap<u32, u32> = HashMap::new(); }\n"
    assert rules_of("model/x.rs", src) == ["hash-iter"]
    assert rules_of("kernels/autotune.rs", src) == ["hash-iter"]
    assert rules_of("coordinator/engine.rs", src) == ["hash-iter"]
    assert rules_of("coordinator/router.rs", src) == ["hash-iter"]
    # kvpage's prefix trie and runtime's executable cache are out of
    # the deterministic-output scope by path.
    assert rules_of("coordinator/kvpage.rs", src) == []
    assert rules_of("runtime/x.rs", src) == []


def test_alloc_rule_and_allowlist():
    hot = "fn step() { let v = Vec::new(); }\n"
    assert rules_of("kernels/exec/x.rs", hot) == ["alloc"]
    assert rules_of("kernels/x.rs", hot) == []
    warm = "fn ensure_tile_scratches() { let v = Vec::new(); }\n"
    assert rules_of("kernels/exec/x.rs", warm) == []
    ctor = "fn new() { let v = vec![0u8; 4]; }\n"
    assert rules_of("kernels/exec/x.rs", ctor) == []
    cap = "fn step() { let v: Vec<u8> = Vec::with_capacity(4); }\n"
    assert rules_of("kernels/exec/x.rs", cap) == []
    annotated = ("fn step() {\n"
                 "    // lint: allow(alloc): per-call bookkeeping\n"
                 "    let v = Vec::new();\n}\n")
    assert rules_of("kernels/exec/x.rs", annotated) == []


def test_wallclock_scopes():
    src = "fn f() { let t = Instant::now(); }\n"
    assert rules_of("kernels/exec/x.rs", src) == ["wallclock"]
    assert rules_of("model/x.rs", src) == ["wallclock"]
    assert rules_of("kernels/autotune.rs", src) == []
    assert rules_of("metrics/mod.rs", src) == []
    assert rules_of("util/bench.rs", src) == []
    # The wire reader's socket deadlines are wall-clock by nature; the
    # rest of http/ stays under the rule.
    assert rules_of("http/proto.rs", src) == []
    assert rules_of("http/reactor.rs", src) == []
    assert rules_of("http/server.rs", src) == ["wallclock"]


def test_panic_message_rule():
    bad = "fn f(rc: u32) { assert!(rc > 0); }\n"
    assert rules_of("coordinator/kvpage.rs", bad) == ["panic-message"]
    good = 'fn f(rc: u32) { assert!(rc > 0, "free block"); }\n'
    assert rules_of("coordinator/kvpage.rs", good) == []
    eq_bad = "fn f(a: u32) { debug_assert_eq!(a, 0); }\n"
    assert rules_of("coordinator/kvpage.rs", eq_bad) == ["panic-message"]
    eq_good = 'fn f(a: u32) { debug_assert_eq!(a, 0, "dirty {a}"); }\n'
    assert rules_of("coordinator/kvpage.rs", eq_good) == []
    multi = ('fn f(a: u32) {\n    assert!(\n        a > 0,\n'
             '        "free block {a}",\n    );\n}\n')
    assert rules_of("coordinator/kvpage.rs", multi) == []
    # Commas nested in the operands are not argument separators.
    nested = "fn f(v: &[u32]) { assert_eq!(v.iter().fold(0, f), 0); }\n"
    assert rules_of("coordinator/kvpage.rs", nested) == ["panic-message"]
    # Out-of-scope files are not held to the message rule.
    assert rules_of("coordinator/x.rs", bad) == []
    # panic! needs a payload string.
    assert rules_of("coordinator/kvpage.rs",
                    "fn f() { panic!(); }\n") == ["panic-message"]
    assert rules_of("coordinator/kvpage.rs",
                    'fn f() { panic!("why: {}", 1); }\n') == []


def test_design_ref_rule():
    ok = "// see DESIGN.md §2 for the substrate\nfn f() {}\n"
    assert rules_of("model/x.rs", ok) == []
    bad = "// see §9 (stale)\nfn f() {}\n"
    assert rules_of("model/x.rs", bad) == ["design-ref"]
    free = "// §Calibration notes\nfn f() {}\n"
    assert rules_of("model/x.rs", free) == []
    # Citations inside test modules still must resolve.
    in_test = "#[cfg(test)]\nmod tests {\n    // pins §7\n}\n"
    assert rules_of("model/x.rs", in_test) == ["design-ref"]


def test_design_sections_parser():
    s = design_sections("# T\n## §1 One\ntext\n## §12 Twelve\n## not\n")
    assert 1 in s and 12 in s and 2 not in s


# ---------------------------------------------------------------------------
# Mutation checks: deliberately break the tree in memory, expect findings
# ---------------------------------------------------------------------------


def real_sections():
    return design_sections(DESIGN.read_text())


def test_mutation_raw_lock_canary():
    """The CI canary in file form: a raw .lock() added to a coordinator
    file must produce a raw-lock finding."""
    path = SRC / "coordinator" / "router.rs"
    mutated = path.read_text() + (
        "\nfn sneaky(m: &std::sync::Mutex<u32>) { let _ = m.lock(); }\n")
    rules = [f[0] for f in
             lint_source("coordinator/router.rs", mutated, real_sections())]
    assert "raw-lock" in rules


def test_mutation_annotation_removal():
    """Stripping any one `lint: allow` annotation from a hot-path file
    must surface at least one finding — proves the annotations are
    load-bearing, not decorative."""
    path = SRC / "coordinator" / "engine.rs"
    text = path.read_text()
    assert "lint: allow(unwrap):" in text
    mutated = text.replace("lint: allow(unwrap):", "lint: was(unwrap):", 1)
    rules = [f[0] for f in
             lint_source("coordinator/engine.rs", mutated, real_sections())]
    assert "unwrap" in rules


def test_mutation_hashmap_reintroduction():
    """Re-introducing a HashMap into the model layer must be flagged."""
    path = SRC / "model" / "mod.rs"
    mutated = path.read_text() + (
        "\nfn sneaky() { let m: std::collections::HashMap<u32, u32> = "
        "std::collections::HashMap::new(); }\n")
    rules = [f[0] for f in
             lint_source("model/mod.rs", mutated, real_sections())]
    assert "hash-iter" in rules


def test_mutation_messageless_assert():
    path = SRC / "coordinator" / "kvpage.rs"
    mutated = path.read_text() + (
        "\nfn sneaky(rc: u32) { assert!(rc > 0); }\n")
    rules = [f[0] for f in
             lint_source("coordinator/kvpage.rs", mutated, real_sections())]
    assert "panic-message" in rules


def test_mutation_dangling_design_ref():
    mutated = "// stale citation §99\nfn f() {}\n"
    rules = [f[0] for f in
             lint_source("model/x.rs", mutated, real_sections())]
    assert rules == ["design-ref"]


def test_mutation_wallclock_in_kernel():
    path = SRC / "kernels" / "exec" / "splitk.rs"
    mutated = path.read_text() + (
        "\nfn sneaky() { let t = std::time::Instant::now(); }\n")
    rules = [f[0] for f in
             lint_source("kernels/exec/splitk.rs", mutated, real_sections())]
    assert "wallclock" in rules


# ---------------------------------------------------------------------------
# The gate: the committed tree is lint-clean
# ---------------------------------------------------------------------------


def test_design_md_has_the_cited_sections():
    s = real_sections()
    # §1..§11 all exist after the HTTP front-door section landed.
    assert s >= set(range(1, 12)), s


def test_repo_tree_is_lint_clean():
    findings = run_lint()
    pretty = "\n".join("%s:%d: [%s] %s" % (f[1], f[2], f[0], f[3])
                       for f in findings)
    assert not findings, "lint findings on the committed tree:\n" + pretty


if __name__ == "__main__":
    fs = run_lint()
    for f in fs:
        print("%s:%d: [%s] %s" % (f[1], f[2], f[0], f[3]))
    print("lint: %s" % ("clean" if not fs else "%d finding(s)" % len(fs)))
