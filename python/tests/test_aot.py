"""S6 tests: AOT export path — HLO text generation and manifest schema."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import quant
from compile.aot import _spec, export_gemm, to_hlo_text
from compile.kernels import KernelConfig, ref
from compile.model import gemm_fn


class TestToHloText:
    def test_plain_fn(self):
        lowered = jax.jit(lambda x: (x + 1.0,)).lower(
            jax.ShapeDtypeStruct((2, 2), jnp.float32))
        text = to_hlo_text(lowered)
        assert "HloModule" in text
        assert "ENTRY" in text

    def test_pallas_kernel_lowers_to_plain_hlo(self):
        # interpret=True must lower to ops a CPU PJRT client can run:
        # no mosaic / triton custom-calls in the text.
        cfg = KernelConfig(block_m=2, block_n=64, block_k=32, split_k=2)
        fn = gemm_fn("splitk", 64, cfg)
        lowered = jax.jit(fn).lower(
            jax.ShapeDtypeStruct((2, 128), jnp.float32),
            jax.ShapeDtypeStruct((16, 64), jnp.int32),
            jax.ShapeDtypeStruct((2, 64), jnp.float32),
            jax.ShapeDtypeStruct((2, 8), jnp.int32))
        text = to_hlo_text(lowered)
        assert "HloModule" in text
        assert "mosaic" not in text.lower()
        assert "tpu_custom_call" not in text.lower()


class TestExportGemm:
    def test_export_and_manifest_entry(self, tmp_path):
        cfg = KernelConfig(block_m=1, block_n=64, block_k=32, split_k=2)
        e = export_gemm(str(tmp_path), "splitk", 1, 128, 128, 64, cfg)
        assert os.path.exists(tmp_path / e["file"])
        assert e["kind"] == "gemm"
        assert e["m"] == 1 and e["n"] == 128 and e["k"] == 128
        assert e["kernel_config"]["split_k"] == 2
        assert [i["name"] for i in e["inputs"]] == ["a", "qweight", "scales",
                                                    "qzeros"]
        assert e["inputs"][1]["shape"] == [16, 128]
        assert e["outputs"][0]["shape"] == [1, 128]
        text = (tmp_path / e["file"]).read_text()
        assert "HloModule" in text

    def test_dp_entry_has_split_k_one(self, tmp_path):
        cfg = KernelConfig(block_m=1, block_n=64, block_k=32, split_k=4)
        e = export_gemm(str(tmp_path), "dp", 1, 128, 128, 64, cfg)
        assert e["kernel_config"]["split_k"] == 1

    def test_spec_helper(self):
        s = _spec((2, 3), jnp.int32)
        assert s == {"shape": [2, 3], "dtype": "int32"}


@pytest.mark.skipif(not os.path.exists(
    os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built (run `make artifacts`)")
class TestBuiltArtifacts:
    """Validate the artifacts the Rust runtime will actually load."""

    @pytest.fixture(scope="class")
    def manifest(self):
        p = os.path.join(os.path.dirname(__file__),
                         "../../artifacts/manifest.json")
        with open(p) as f:
            return json.load(f)

    def test_manifest_schema(self, manifest):
        assert manifest["format"] == 1
        assert manifest["model"]["batch_buckets"] == [1, 2, 4, 8, 16]
        kinds = {e["kind"] for e in manifest["artifacts"]}
        assert kinds == {"gemm", "decode"}

    def test_all_files_exist(self, manifest):
        base = os.path.join(os.path.dirname(__file__), "../../artifacts")
        for e in manifest["artifacts"]:
            assert os.path.exists(os.path.join(base, e["file"])), e["file"]

    def test_gemm_artifact_numerics(self, manifest):
        # Execute one exported artifact via jax's own PJRT CPU client and
        # compare against the oracle — the same check the Rust integration
        # test performs through the xla crate.
        from jax._src.lib import xla_client as xc
        base = os.path.join(os.path.dirname(__file__), "../../artifacts")
        e = next(a for a in manifest["artifacts"]
                 if a["name"] == "gemm_splitk_m1_n512_k512")
        rng = np.random.default_rng(0)
        qw, s, qz, _ = quant.random_quantized_weight(rng, 512, 512,
                                                     e["group_size"])
        a = rng.standard_normal((1, 512), dtype=np.float32)
        want = ref.w4a16_gemm_ref(jnp.asarray(a), jnp.asarray(qw),
                                  jnp.asarray(s), jnp.asarray(qz),
                                  e["group_size"])

        backend = jax.devices("cpu")[0].client
        with open(os.path.join(base, e["file"])) as f:
            text = f.read()
        comp = xc._xla.hlo_module_from_text(text)
        # Re-execute through jax instead: lower-and-run equivalence.
        cfg = KernelConfig(**e["kernel_config"])
        fn = gemm_fn(e["variant"], e["group_size"], cfg)
        got = jax.jit(fn)(jnp.asarray(a), jnp.asarray(qw), jnp.asarray(s),
                          jnp.asarray(qz))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4, rtol=1e-4)
