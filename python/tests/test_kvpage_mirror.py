"""Python mirror of the Rust paged KV memory manager
(`rust/src/coordinator/kvpage.rs`): block pool allocation/refcount
semantics, the FNV-1a prompt chain hash, copy-on-write prefix sharing,
and LRU eviction of cached blocks.

The Rust growth environment has no cargo toolchain, so — as with the
StreamK and micro-kernel mirrors — the allocator and trie logic is
cross-validated here against the same invariants the Rust unit tests
and the chaos suite's block ledger pin:

* the pool hands out ascending block ids from a fresh pool and recycles
  LIFO; `allocated == freed + outstanding` at every step; releasing a
  free block (double free) and retaining a free block both fail loudly;
* the chain hash reproduces pinned known-answer vectors shared with
  `kvpage.rs::tests::chain_hash_pins_shared_vectors` (cross-language
  agreement without cross-execution), and depends on ancestry — two
  blocks with identical tokens but different parents never collide;
* prefix attach serves `min(full_blocks * block_len, plen - 1)`
  positions from the cache (the final prompt position is always
  recomputed), shares blocks by refcount, and a write into a shared
  block forks it first — the original owner's rows survive bit-exact;
* eviction under pressure frees exactly the least-recently-used cached
  blocks nobody else references;
* a seeded random attach/extend/register/free trace keeps every
  refcount equal to (table references + trie references) per block and
  drains to a fully-free pool.

Run standalone for the full randomized sweep:
`python tests/test_kvpage_mirror.py`
"""

import random

import numpy as np

MASK64 = (1 << 64) - 1


def chain_hash(parent, tokens):
    """Mirror of `kvpage::chain_hash`: FNV-1a 64 over the parent hash
    (8 LE bytes) then each token (4 LE bytes, two's-complement u32)."""
    h = 0xCBF29CE484222325
    prime = 0x100000001B3
    for byte in int(parent).to_bytes(8, "little"):
        h = ((h ^ byte) * prime) & MASK64
    for t in tokens:
        for byte in (int(t) & 0xFFFFFFFF).to_bytes(4, "little"):
            h = ((h ^ byte) * prime) & MASK64
    return h


class BlockPool:
    """Mirror of `kvpage::BlockPool`."""

    def __init__(self, total, block_len):
        assert block_len >= 1 and total >= 1
        self.block_len = block_len
        self.free = list(range(total - 1, -1, -1))
        self.refcount = [0] * total
        self.allocated = 0
        self.freed = 0

    def total(self):
        return len(self.refcount)

    def outstanding(self):
        return self.total() - len(self.free)

    def is_shared(self, b):
        return self.refcount[b] > 1

    def alloc(self):
        if not self.free:
            return None
        b = self.free.pop()
        assert self.refcount[b] == 0
        self.refcount[b] = 1
        self.allocated += 1
        return b

    def retain(self, b):
        assert self.refcount[b] > 0, f"retain of a free KV block {b}"
        self.refcount[b] += 1

    def release(self, b):
        assert self.refcount[b] > 0, f"double free of KV block {b}"
        self.refcount[b] -= 1
        if self.refcount[b] == 0:
            self.free.append(b)
            self.freed += 1
            return True
        return False


class PagedKv:
    """Mirror of `kvpage::PagedKv` (same stride math; one f32 row per
    (layer, k|v, head, pos))."""

    def __init__(self, n_layers, n_heads, head_dim, slots, blocks,
                 block_len, prefix_cache):
        self.pool = BlockPool(blocks, block_len)
        self.n_heads = n_heads
        self.head_dim = head_dim
        self.block_stride = n_layers * 2 * n_heads * block_len * head_dim
        self.data = np.zeros(blocks * self.block_stride, dtype=np.float32)
        self.tables = [[] for _ in range(slots)]
        self.used = [0] * slots
        self.registered = [0] * slots
        self.reg_hash = [0] * slots
        # hash -> [block, last_used]; None when the trie is disabled.
        self.prefix = {} if prefix_cache else None
        self.clock = 0
        self.forks = 0
        self.evictions = 0

    def _row_start(self, slot, layer, kv, head, pos):
        l = self.pool.block_len
        block = self.tables[slot][pos // l]
        in_block = ((layer * 2 + kv) * self.n_heads + head) * l + pos % l
        return block * self.block_stride + in_block * self.head_dim

    def row(self, slot, layer, kv, head, pos):
        o = self._row_start(slot, layer, kv, head, pos)
        return self.data[o:o + self.head_dim]

    def write_row(self, slot, layer, kv, head, pos, row):
        l = self.pool.block_len
        block = self.tables[slot][pos // l]
        assert not self.pool.is_shared(block), \
            f"write to shared KV block {block} (missing COW fork)"
        o = self._row_start(slot, layer, kv, head, pos)
        self.data[o:o + self.head_dim] = row
        self.used[slot] = max(self.used[slot], pos + 1)

    def writable(self, slot, pos):
        l = self.pool.block_len
        bi = pos // l
        return bi < len(self.tables[slot]) and \
            not self.pool.is_shared(self.tables[slot][bi])

    def _evict_lru(self):
        if not self.prefix:
            return False
        victims = [(e[1], h) for h, e in self.prefix.items()
                   if self.pool.refcount[e[0]] == 1]
        if not victims:
            return False
        _, h = min(victims)
        block = self.prefix.pop(h)[0]
        assert self.pool.release(block)
        self.evictions += 1
        return True

    def _alloc_or_evict(self):
        while True:
            b = self.pool.alloc()
            if b is not None:
                return b
            if not self._evict_lru():
                return None

    def attach_prefix(self, slot, prompt):
        assert not self.tables[slot], "attach on a non-empty table"
        self.used[slot] = self.registered[slot] = self.reg_hash[slot] = 0
        if self.prefix is None:
            return 0
        l = self.pool.block_len
        h, matched = 0, []
        for bi in range(len(prompt) // l):
            nh = chain_hash(h, prompt[bi * l:(bi + 1) * l])
            if nh not in self.prefix:
                break
            matched.append(self.prefix[nh][0])
            self.prefix[nh][1] = self.clock
            self.clock += 1
            h = nh
        if not matched:
            return 0
        cached = min(len(matched) * l, len(prompt) - 1)
        for b in matched:
            self.pool.retain(b)
            self.tables[slot].append(b)
        self.used[slot] = cached
        self.registered[slot] = len(matched)
        self.reg_hash[slot] = h
        return cached

    def register_prompt(self, slot, prompt, consumed):
        if self.prefix is None:
            return
        l = self.pool.block_len
        limit = min(consumed, len(prompt))
        while (self.registered[slot] + 1) * l <= limit:
            bi = self.registered[slot]
            h = chain_hash(self.reg_hash[slot],
                           prompt[bi * l:(bi + 1) * l])
            block = self.tables[slot][bi]
            if h in self.prefix:
                self.prefix[h][1] = self.clock
                self.clock += 1
            else:
                self.pool.retain(block)
                self.prefix[h] = [block, self.clock]
                self.clock += 1
            self.reg_hash[slot] = h
            self.registered[slot] += 1

    def reserve(self, slot, lo, hi):
        """Returns False on KvPressure (pool truly exhausted)."""
        l = self.pool.block_len
        for bi in range(lo // l, hi // l + 1):
            if bi < len(self.tables[slot]):
                if self.pool.is_shared(self.tables[slot][bi]):
                    if not self._fork(slot, bi):
                        return False
            else:
                assert bi == len(self.tables[slot])
                b = self._alloc_or_evict()
                if b is None:
                    return False
                self.tables[slot].append(b)
        return True

    def _fork(self, slot, bi):
        old = self.tables[slot][bi]
        new = self._alloc_or_evict()
        if new is None:
            return False
        s, d = old * self.block_stride, new * self.block_stride
        self.data[d:d + self.block_stride] = \
            self.data[s:s + self.block_stride]
        self.pool.release(old)
        self.tables[slot][bi] = new
        self.forks += 1
        return True

    def free_slot(self, slot):
        for b in self.tables[slot]:
            self.pool.release(b)
        self.tables[slot] = []
        self.used[slot] = self.registered[slot] = self.reg_hash[slot] = 0

    def cached_blocks(self):
        return len(self.prefix) if self.prefix else 0


# ---- chain hash ------------------------------------------------------


def test_chain_hash_pins_shared_vectors():
    # Known-answer vectors shared with kvpage.rs — both sides must
    # agree on these exact integers.
    assert chain_hash(0, [3, 5, 7, 11]) == 0xEFC5F622C224F58F
    assert chain_hash(0xEFC5F622C224F58F, [1, 2, 3, 4]) \
        == 0x1C9F65A4DF74FFEB
    assert chain_hash(0, []) == 0xA8C7F832281A39C5


def test_chain_hash_depends_on_ancestry():
    a = chain_hash(chain_hash(0, [1, 2]), [9, 9])
    b = chain_hash(chain_hash(0, [3, 4]), [9, 9])
    assert a != b
    # Negative tokens hash via two's complement, not an error.
    assert chain_hash(0, [-1]) != chain_hash(0, [1])


# ---- block pool ------------------------------------------------------


def test_pool_allocates_ascending_and_recycles_lifo():
    p = BlockPool(3, 16)
    assert [p.alloc() for _ in range(3)] == [0, 1, 2]
    assert p.alloc() is None
    assert p.release(1)
    assert p.alloc() == 1, "LIFO recycle"
    assert p.outstanding() == 3
    assert (p.allocated, p.freed) == (4, 1)


def test_pool_refcounts_and_ledger():
    p = BlockPool(2, 4)
    b = p.alloc()
    p.retain(b)
    assert p.is_shared(b)
    assert not p.release(b), "shared release keeps the block"
    assert p.release(b), "last release frees"
    assert p.allocated == p.freed + p.outstanding() == 1


def test_pool_double_free_and_retain_free_raise():
    p = BlockPool(2, 4)
    b = p.alloc()
    p.release(b)
    for bad in (lambda: p.release(b), lambda: p.retain(b)):
        try:
            bad()
        except AssertionError:
            pass
        else:
            raise AssertionError("expected a loud failure")


# ---- prefix sharing + COW -------------------------------------------


def _paged(slots, blocks, prefix=True):
    # 2 layers, 2 heads, head_dim 4, block_len 4 — the same tiny shape
    # the Rust unit tests use.
    return PagedKv(2, 2, 4, slots, blocks, 4, prefix)


def test_prefix_attach_skips_cached_positions():
    kv = _paged(2, 8)
    prompt = list(range(10))
    assert kv.attach_prefix(0, prompt) == 0, "cold cache"
    assert kv.reserve(0, 0, 9)
    for pos in range(10):
        kv.write_row(0, 0, 0, 0, pos, np.full(4, pos, dtype=np.float32))
    kv.register_prompt(0, prompt, 10)
    assert kv.cached_blocks() == 2, "blocks 0,1 full; block 2 partial"

    cached = kv.attach_prefix(1, prompt)
    assert cached == 8 and kv.used[1] == 8
    for pos in range(8):
        assert np.array_equal(kv.row(1, 0, 0, 0, pos),
                              np.full(4, pos, dtype=np.float32))
    assert kv.reserve(1, 8, 9)
    kv.write_row(1, 0, 0, 0, 8, np.full(4, 99.0, dtype=np.float32))
    assert np.array_equal(kv.row(0, 0, 0, 0, 8),
                          np.full(4, 8.0, dtype=np.float32)), \
        "slot 0's row untouched"
    assert kv.forks == 0, "partial tail block was never shared"


def test_cow_fork_on_write_into_shared_block():
    kv = _paged(2, 8)
    prompt = list(range(8))  # block-aligned: the tail block is shared
    kv.attach_prefix(0, prompt)
    assert kv.reserve(0, 0, 7)
    for pos in range(8):
        kv.write_row(0, 0, 0, 0, pos, np.full(4, pos, dtype=np.float32))
    kv.register_prompt(0, prompt, 8)

    cached = kv.attach_prefix(1, prompt)
    assert cached == 7, "final prompt position always recomputed"
    assert not kv.writable(1, 7), "tail attached shared"
    assert kv.reserve(1, 7, 7)
    assert kv.forks == 1 and kv.writable(1, 7)
    kv.write_row(1, 0, 0, 0, 7, np.full(4, -1.0, dtype=np.float32))
    assert np.array_equal(kv.row(0, 0, 0, 0, 7),
                          np.full(4, 7.0, dtype=np.float32)), \
        "original owner's row survives the fork"
    assert np.array_equal(kv.row(1, 0, 0, 0, 6),
                          np.full(4, 6.0, dtype=np.float32)), \
        "fork carried the cached rows over"


def test_write_into_shared_block_without_fork_raises():
    kv = _paged(2, 8)
    prompt = list(range(8))
    kv.attach_prefix(0, prompt)
    kv.reserve(0, 0, 7)
    for pos in range(8):
        kv.write_row(0, 0, 0, 0, pos, np.zeros(4, dtype=np.float32))
    kv.register_prompt(0, prompt, 8)
    kv.attach_prefix(1, prompt)
    try:
        kv.write_row(1, 0, 0, 0, 7, np.ones(4, dtype=np.float32))
    except AssertionError as e:
        assert "COW" in str(e)
    else:
        raise AssertionError("shared write must fail loudly")


def test_lru_eviction_frees_least_recently_used_first():
    kv = _paged(1, 3)
    for lo in (0, 4):
        prompt = list(range(lo, lo + 4))
        kv.attach_prefix(0, prompt)
        assert kv.reserve(0, 0, 3)
        for pos in range(4):
            kv.write_row(0, 0, 0, 0, pos, np.zeros(4, dtype=np.float32))
        kv.register_prompt(0, prompt, 4)
        kv.free_slot(0)
    assert kv.cached_blocks() == 2
    # Touch the first prompt so the second becomes LRU; then demand all
    # three blocks — both cached entries must evict, LRU first.
    assert kv.attach_prefix(0, list(range(4))) == 3
    kv.free_slot(0)
    assert kv.reserve(0, 0, 11)
    assert kv.evictions == 2 and kv.cached_blocks() == 0


# ---- randomized trace: refcount + ledger invariants ------------------


def _check_invariants(kv):
    # Every block's refcount equals its table references plus its trie
    # references; the lifetime ledger balances.
    refs = [0] * kv.pool.total()
    for table in kv.tables:
        for b in table:
            refs[b] += 1
    if kv.prefix:
        for b, _ in kv.prefix.values():
            refs[b] += 1
    assert refs == kv.pool.refcount, \
        f"refcount drift: held {refs} vs pool {kv.pool.refcount}"
    assert kv.pool.allocated == kv.pool.freed + kv.pool.outstanding()


def test_random_trace_holds_refcount_invariants(iters=200):
    rng = random.Random(1234)
    for _ in range(iters):
        slots, blocks = rng.randint(1, 3), rng.randint(4, 10)
        kv = _paged(slots, blocks, prefix=rng.random() < 0.8)
        prompts = [None] * slots
        # A small pool of shared prompts so attaches actually hit.
        corpus = [[rng.randrange(512) for _ in range(rng.randint(1, 12))]
                  for _ in range(3)]
        for _ in range(rng.randint(5, 40)):
            s = rng.randrange(slots)
            if prompts[s] is None:
                prompt = list(rng.choice(corpus))
                cached = kv.attach_prefix(s, prompt)
                assert cached <= max(0, len(prompt) - 1)
                # Reserve only the positions prefill will write — the
                # engine never reserves (and so never forks) fully
                # cached leading blocks.
                if not kv.reserve(s, cached, len(prompt) - 1):
                    kv.free_slot(s)
                    continue
                for pos in range(cached, len(prompt)):
                    kv.write_row(s, 0, 0, 0, pos,
                                 np.zeros(4, dtype=np.float32))
                kv.register_prompt(s, prompt, len(prompt))
                prompts[s] = prompt
            elif rng.random() < 0.5:
                # Extend the sequence by one decoded position.
                pos = kv.used[s]
                if kv.reserve(s, pos, pos):
                    kv.write_row(s, 0, 0, 0, pos,
                                 np.zeros(4, dtype=np.float32))
                else:
                    kv.free_slot(s)
                    prompts[s] = None
            else:
                kv.free_slot(s)
                prompts[s] = None
            _check_invariants(kv)
        for s in range(slots):
            if prompts[s] is not None:
                kv.free_slot(s)
        # Flush the trie: the pool must drain to fully free.
        if kv.prefix:
            for h in list(kv.prefix):
                kv.pool.release(kv.prefix.pop(h)[0])
        assert kv.pool.outstanding() == 0
        assert kv.pool.allocated == kv.pool.freed
        _check_invariants(kv)


def main():
    test_chain_hash_pins_shared_vectors()
    test_chain_hash_depends_on_ancestry()
    test_pool_allocates_ascending_and_recycles_lifo()
    test_pool_refcounts_and_ledger()
    test_pool_double_free_and_retain_free_raise()
    test_prefix_attach_skips_cached_positions()
    test_cow_fork_on_write_into_shared_block()
    test_write_into_shared_block_without_fork_raises()
    test_lru_eviction_frees_least_recently_used_first()
    test_random_trace_holds_refcount_invariants(iters=1000)
    print("kvpage mirror: all invariants hold")


if __name__ == "__main__":
    main()
