"""GPTQ-style W4 packing / quantization substrate (build-time only).

Format (matches the paper's GPTQ-style inputs, S1 in DESIGN.md):

  * ``qweight``: int32[K//8, N]   — 8 int4 nibbles packed along K.
    Nibble ``i`` (bits ``4*i .. 4*i+3``) of ``qweight[r, n]`` holds the
    quantized value of logical weight row ``r*8 + i``, column ``n``.
  * ``scales``:  float[K//G, N]   — per-(group, column) scale.
  * ``qzeros``:  int32[K//G, N//8] — per-(group, column) zero points,
    8 int4 nibbles packed along N (nibble ``n % 8`` of column ``n``).

Dequantization: ``w[k, n] = (q[k, n] - z[k//G, n]) * s[k//G, n]``.

This mirrors AutoGPTQ's storage minus the ``g_idx`` permutation (we use
contiguous groups) and minus the historical ``zeros - 1`` bias quirk.
"""

from __future__ import annotations

import numpy as np

PACK_FACTOR = 8  # int4 values per int32
QMAX = 15  # unsigned 4-bit range [0, 15]


def pack_along_rows(q: np.ndarray) -> np.ndarray:
    """Pack uint4 values (rows are the packed axis) into int32.

    ``q``: integer array [K, N] with values in [0, 15].
    Returns int32 [K//8, N].
    """
    k, n = q.shape
    if k % PACK_FACTOR != 0:
        raise ValueError(f"K={k} must be a multiple of {PACK_FACTOR}")
    if q.min() < 0 or q.max() > QMAX:
        raise ValueError("quantized values out of int4 range [0, 15]")
    q = q.astype(np.uint32).reshape(k // PACK_FACTOR, PACK_FACTOR, n)
    shifts = (4 * np.arange(PACK_FACTOR, dtype=np.uint32)).reshape(1, PACK_FACTOR, 1)
    packed = np.bitwise_or.reduce(q << shifts, axis=1)
    return packed.view(np.int32)


def unpack_along_rows(packed: np.ndarray) -> np.ndarray:
    """Inverse of :func:`pack_along_rows`. int32 [K//8, N] -> uint8 [K, N]."""
    kp, n = packed.shape
    u = packed.view(np.uint32)[:, None, :]  # [K//8, 1, N]
    shifts = (4 * np.arange(PACK_FACTOR, dtype=np.uint32)).reshape(1, PACK_FACTOR, 1)
    q = (u >> shifts) & 0xF
    return q.reshape(kp * PACK_FACTOR, n).astype(np.uint8)


def pack_along_cols(q: np.ndarray) -> np.ndarray:
    """Pack uint4 values (cols are the packed axis) into int32.

    ``q``: integer array [G, N] with values in [0, 15].
    Returns int32 [G, N//8].
    """
    g, n = q.shape
    if n % PACK_FACTOR != 0:
        raise ValueError(f"N={n} must be a multiple of {PACK_FACTOR}")
    if q.min() < 0 or q.max() > QMAX:
        raise ValueError("quantized values out of int4 range [0, 15]")
    q = q.astype(np.uint32).reshape(g, n // PACK_FACTOR, PACK_FACTOR)
    shifts = (4 * np.arange(PACK_FACTOR, dtype=np.uint32)).reshape(1, 1, PACK_FACTOR)
    packed = np.bitwise_or.reduce(q << shifts, axis=2)
    return packed.view(np.int32)


def unpack_along_cols(packed: np.ndarray) -> np.ndarray:
    """Inverse of :func:`pack_along_cols`. int32 [G, N//8] -> uint8 [G, N]."""
    g, npk = packed.shape
    u = packed.view(np.uint32)[:, :, None]  # [G, N//8, 1]
    shifts = (4 * np.arange(PACK_FACTOR, dtype=np.uint32)).reshape(1, 1, PACK_FACTOR)
    q = (u >> shifts) & 0xF
    return q.reshape(g, npk * PACK_FACTOR).astype(np.uint8)


def quantize_weight(w: np.ndarray, group_size: int):
    """Asymmetric per-(group, column) int4 quantization of ``w`` [K, N].

    Returns ``(qweight int32[K//8, N], scales f32[K//G, N],
    qzeros int32[K//G, N//8])``.
    """
    k, n = w.shape
    if k % group_size != 0:
        raise ValueError(f"K={k} must be a multiple of group_size={group_size}")
    groups = k // group_size
    wg = w.reshape(groups, group_size, n).astype(np.float32)
    # Extend the range to include 0 (standard asymmetric-quant practice):
    # guarantees 0.0 is exactly representable and keeps constant groups
    # from degenerating to a ~0 scale.
    wmax = np.maximum(wg.max(axis=1), 0.0)  # [G, N]
    wmin = np.minimum(wg.min(axis=1), 0.0)
    scales = np.maximum((wmax - wmin) / QMAX, 1e-8).astype(np.float32)
    zeros = np.clip(np.round(-wmin / scales), 0, QMAX).astype(np.uint8)
    q = np.clip(
        np.round(wg / scales[:, None, :]) + zeros[:, None, :].astype(np.float32),
        0,
        QMAX,
    ).astype(np.uint8)
    qweight = pack_along_rows(q.reshape(k, n))
    qzeros = pack_along_cols(zeros)
    return qweight, scales, qzeros


def dequantize(qweight: np.ndarray, scales: np.ndarray, qzeros: np.ndarray,
               group_size: int) -> np.ndarray:
    """Reference dequantization to f32 [K, N] (numpy; mirrors ref.py)."""
    q = unpack_along_rows(qweight).astype(np.float32)  # [K, N]
    z = unpack_along_cols(qzeros).astype(np.float32)  # [G, N]
    k, n = q.shape
    groups = k // group_size
    s = scales.astype(np.float32)
    q = q.reshape(groups, group_size, n)
    w = (q - z[:, None, :]) * s[:, None, :]
    return w.reshape(k, n)


def random_quantized_weight(rng: np.random.Generator, k: int, n: int,
                            group_size: int, scale: float = 0.02):
    """Random fp weight -> quantized tuple; returns (qweight, scales, qzeros, w_dequant)."""
    w = rng.standard_normal((k, n), dtype=np.float32) * scale
    qweight, scales, qzeros = quantize_weight(w, group_size)
    wd = dequantize(qweight, scales, qzeros, group_size)
    return qweight, scales, qzeros, wd
