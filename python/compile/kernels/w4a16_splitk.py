"""Fused W4A16 dequant + GEMM with **SplitK** work decomposition (S2).

TPU/Pallas adaptation of the paper's Triton kernel (Algorithm 1):

* Triton launches a 2-D grid ``(pid, pid_k)`` where ``pid_k`` indexes the
  ``split_k`` partial-sum blocks, each striding through the k-blocks with
  stride ``split_k``, and merges partials with ``tl.atomic_add``.
* Here the grid is ``(m_tiles, n_tiles, split_k, inner_k)``; the output
  ``BlockSpec`` maps every ``(s, t)`` to the same ``(i, j)`` tile, so all
  k-slices *revisit* the output block and accumulate ``o_ref += acc``.
  On a real TPU the two k axes are ``"arbitrary"`` (sequential per core),
  which is the TPU-idiomatic analogue of the GPU's exclusive atomic write;
  under ``interpret=True`` grid steps are sequential by construction.
  DESIGN.md §9 spells out the full mapping.

The dequantization is fused: the packed int32 weight block is unpacked
(shift/mask), shifted by the per-group zero point and scaled in-kernel,
immediately before the MXU dot — exactly the paper's one-step fused
dequant-GEMM, never materializing the fp16 weight matrix in HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import PACK_FACTOR, KernelConfig, cdiv, dequant_block


def _kernel(a_ref, qw_ref, scale_ref, qz_ref, o_ref, *, block_k: int,
            block_n: int, compute_dtype):
    s = pl.program_id(2)
    t = pl.program_id(3)

    # First visit to this output tile: zero it (the Triton kernel relies on
    # a zeroed C buffer; we fold the zeroing into the kernel itself).
    @pl.when(jnp.logical_and(s == 0, t == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(compute_dtype)
    b = dequant_block(qw_ref[...], scale_ref[...], qz_ref[...], block_k,
                      block_n, compute_dtype)
    acc = jnp.dot(a, b, preferred_element_type=jnp.float32)
    # Partial-sum merge — the atomic_add analogue (see module docstring).
    o_ref[...] += acc.astype(o_ref.dtype)


def w4a16_gemm_splitk(a, qweight, scales, qzeros, *, group_size: int,
                      config: KernelConfig | None = None,
                      out_dtype=jnp.float32, interpret: bool = True):
    """``C[m,n] = A[m,k] @ dequant(qweight)[k,n]`` via SplitK decomposition.

    Args:
      a:       activations ``[m, k]`` (f32/bf16/f16).
      qweight: packed int4 weights ``int32 [k//8, n]``.
      scales:  ``[k//group_size, n]``.
      qzeros:  packed zero points ``int32 [k//group_size, n//8]``.
      group_size: quantization group length along k.
      config:  launch configuration (block sizes + split_k + ordering).
      out_dtype: output/accumulator dtype of the C buffer.
      interpret: must stay True on CPU-PJRT (Mosaic custom-calls cannot
        run there); the lowered HLO is what the Rust runtime executes.
    """
    config = config or KernelConfig()
    m, k = a.shape
    kp, n = qweight.shape
    if kp * PACK_FACTOR != k:
        raise ValueError(f"qweight rows {kp} != k/8 = {k // PACK_FACTOR}")
    config.validate(m, n, k, group_size)

    block_m = min(config.block_m, m)
    block_n, block_k, split_k = config.block_n, config.block_k, config.split_k
    inner_k = k // (block_k * split_k)
    grid = (cdiv(m, block_m), cdiv(n, block_n), split_k, inner_k)
    strided = config.ordering == "strided"

    def kb(s, t):
        # k-block index owned by (split s, inner step t).
        return t * split_k + s if strided else s * inner_k + t

    pack = PACK_FACTOR
    a_spec = pl.BlockSpec((block_m, block_k), lambda i, j, s, t: (i, kb(s, t)))
    qw_spec = pl.BlockSpec((block_k // pack, block_n),
                           lambda i, j, s, t: (kb(s, t), j))
    scale_spec = pl.BlockSpec((1, block_n),
                              lambda i, j, s, t: (kb(s, t) * block_k // group_size, j))
    qz_spec = pl.BlockSpec((1, block_n // pack),
                           lambda i, j, s, t: (kb(s, t) * block_k // group_size, j))
    o_spec = pl.BlockSpec((block_m, block_n), lambda i, j, s, t: (i, j))

    kernel = functools.partial(_kernel, block_k=block_k, block_n=block_n,
                               compute_dtype=jnp.float32)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[a_spec, qw_spec, scale_spec, qz_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
    )(a, qweight, scales, qzeros)
