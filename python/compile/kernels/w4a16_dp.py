"""Fused W4A16 dequant + GEMM with **Data-Parallel** decomposition (S3).

The paper's baseline: one "thread block" — here one ``(i, j)`` grid tile —
is solely responsible for the complete multiply-accumulate over the full
k extent of its output tile (the classic blocked GEMM). The k loop is the
third grid axis; since every k-step of a given ``(i, j)`` maps to the same
output block, there is no cross-tile partial-sum merge — the defining
contrast with the SplitK kernel.

Dequantization is fused identically to the SplitK kernel so the comparison
isolates the *decomposition*, exactly as the paper's experiments do ("we
fixed the tile sizes ... to isolate the impact of SplitK").
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import PACK_FACTOR, KernelConfig, cdiv, dequant_block


def _kernel(a_ref, qw_ref, scale_ref, qz_ref, o_ref, *, block_k: int,
            block_n: int, compute_dtype):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(compute_dtype)
    b = dequant_block(qw_ref[...], scale_ref[...], qz_ref[...], block_k,
                      block_n, compute_dtype)
    acc = jnp.dot(a, b, preferred_element_type=jnp.float32)
    o_ref[...] += acc.astype(o_ref.dtype)


def w4a16_gemm_dp(a, qweight, scales, qzeros, *, group_size: int,
                  config: KernelConfig | None = None,
                  out_dtype=jnp.float32, interpret: bool = True):
    """``C = A @ dequant(qweight)`` with the data-parallel (blocked) schedule.

    Same signature as :func:`w4a16_gemm_splitk`; ``config.split_k`` and
    ``config.ordering`` are ignored (DP is the ``split_k == 1`` limit).
    """
    config = config or KernelConfig()
    m, k = a.shape
    kp, n = qweight.shape
    if kp * PACK_FACTOR != k:
        raise ValueError(f"qweight rows {kp} != k/8 = {k // PACK_FACTOR}")
    # Validate as if split_k == 1.
    KernelConfig(config.block_m, config.block_n, config.block_k, 1,
                 "contiguous").validate(m, n, k, group_size)

    block_m = min(config.block_m, m)
    block_n, block_k = config.block_n, config.block_k
    grid = (cdiv(m, block_m), cdiv(n, block_n), k // block_k)

    pack = PACK_FACTOR
    a_spec = pl.BlockSpec((block_m, block_k), lambda i, j, t: (i, t))
    qw_spec = pl.BlockSpec((block_k // pack, block_n), lambda i, j, t: (t, j))
    scale_spec = pl.BlockSpec((1, block_n),
                              lambda i, j, t: (t * block_k // group_size, j))
    qz_spec = pl.BlockSpec((1, block_n // pack),
                           lambda i, j, t: (t * block_k // group_size, j))
    o_spec = pl.BlockSpec((block_m, block_n), lambda i, j, t: (i, j))

    kernel = functools.partial(_kernel, block_k=block_k, block_n=block_n,
                               compute_dtype=jnp.float32)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[a_spec, qw_spec, scale_spec, qz_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
    )(a, qweight, scales, qzeros)
