"""L1 — Pallas fused W4A16 dequant-GEMM kernels (interpret=True on CPU).

Public surface:
  * :func:`w4a16_gemm_splitk` — the paper's SplitK fused kernel (S2).
  * :func:`w4a16_gemm_dp` — the data-parallel baseline (S3).
  * :class:`KernelConfig` — block sizes / split_k / k-ordering.
  * :mod:`ref` — pure-jnp oracle (S4).
"""

from .common import KernelConfig, PACK_FACTOR, cdiv
from .w4a16_splitk import w4a16_gemm_splitk
from .w4a16_dp import w4a16_gemm_dp
from . import ref

__all__ = [
    "KernelConfig",
    "PACK_FACTOR",
    "cdiv",
    "w4a16_gemm_splitk",
    "w4a16_gemm_dp",
    "ref",
]
