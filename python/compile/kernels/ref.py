"""Pure-jnp correctness oracle for the fused W4A16 kernels (S4).

This is the ground truth every Pallas kernel variant is validated against
in pytest: unpack int4 -> dequantize -> matmul, written with plain jnp ops
only (no pallas, no custom calls), so it runs anywhere and its numerics are
trivially auditable.
"""

from __future__ import annotations

import jax.numpy as jnp

PACK_FACTOR = 8


def unpack_rows(qweight: jnp.ndarray) -> jnp.ndarray:
    """int32 [K//8, N] -> int32 [K, N] of values in [0, 15] (packed along K)."""
    kp, n = qweight.shape
    shifts = (4 * jnp.arange(PACK_FACTOR, dtype=jnp.int32)).reshape(1, PACK_FACTOR, 1)
    q = (qweight[:, None, :] >> shifts) & 0xF
    return q.reshape(kp * PACK_FACTOR, n)


def unpack_cols(qzeros: jnp.ndarray) -> jnp.ndarray:
    """int32 [G, N//8] -> int32 [G, N] of values in [0, 15] (packed along N)."""
    g, npk = qzeros.shape
    shifts = (4 * jnp.arange(PACK_FACTOR, dtype=jnp.int32)).reshape(1, 1, PACK_FACTOR)
    z = (qzeros[:, :, None] >> shifts) & 0xF
    return z.reshape(g, npk * PACK_FACTOR)


def dequantize(qweight: jnp.ndarray, scales: jnp.ndarray, qzeros: jnp.ndarray,
               group_size: int, dtype=jnp.float32) -> jnp.ndarray:
    """Dequantize to ``dtype`` [K, N]: ``(q - z) * s`` with per-group s, z."""
    q = unpack_rows(qweight).astype(jnp.float32)  # [K, N]
    z = unpack_cols(qzeros).astype(jnp.float32)  # [G, N]
    k, n = q.shape
    groups = k // group_size
    q = q.reshape(groups, group_size, n)
    s = scales.astype(jnp.float32)
    w = (q - z[:, None, :]) * s[:, None, :]
    return w.reshape(k, n).astype(dtype)


def w4a16_gemm_ref(a: jnp.ndarray, qweight: jnp.ndarray, scales: jnp.ndarray,
                   qzeros: jnp.ndarray, group_size: int) -> jnp.ndarray:
    """Oracle for ``C = A @ dequant(B)``; accumulates in f32, returns a.dtype."""
    w = dequantize(qweight, scales, qzeros, group_size, dtype=a.dtype)
    out = jnp.dot(a, w, preferred_element_type=jnp.float32)
    return out.astype(a.dtype)
