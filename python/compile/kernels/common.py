"""Shared pieces of the fused W4A16 Pallas kernels (L1).

Both decompositions (SplitK and Data-Parallel) share the same in-kernel
dequantization: unpack int4 nibbles from the packed int32 VMEM block with
shift/mask (the Triton kernel's ``>>``/``& 0xF``), subtract the per-group
zero point, multiply by the per-group scale, and feed the MXU ``jnp.dot``.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

PACK_FACTOR = 8


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """Launch configuration — the analogue of the Triton kernel's
    ``BLOCK_M/BLOCK_N/BLOCK_K`` + ``SPLIT_K`` meta-parameters.

    ``ordering`` selects how the k-blocks are distributed over the split_k
    grid axis: ``"strided"`` matches the paper's Algorithm 1 (block ``s``
    handles k-blocks ``s, s+split_k, ...``); ``"contiguous"`` gives each
    split a contiguous k-range (the TPU-friendlier schedule, better HBM
    locality per core). Numerics are identical up to f32 summation order.
    """

    block_m: int = 16
    block_n: int = 64
    block_k: int = 64
    split_k: int = 4
    ordering: str = "strided"

    def validate(self, m: int, n: int, k: int, group_size: int) -> None:
        if self.block_k % PACK_FACTOR != 0:
            raise ValueError(f"block_k={self.block_k} must be a multiple of 8")
        if self.block_n % PACK_FACTOR != 0:
            raise ValueError(f"block_n={self.block_n} must be a multiple of 8")
        if group_size % self.block_k != 0:
            raise ValueError(
                f"group_size={group_size} must be a multiple of block_k={self.block_k} "
                "(each k-block reads exactly one scale/zero row)")
        if k % (self.block_k * self.split_k) != 0:
            raise ValueError(
                f"k={k} must be a multiple of block_k*split_k="
                f"{self.block_k * self.split_k}")
        if n % self.block_n != 0:
            raise ValueError(f"n={n} must be a multiple of block_n={self.block_n}")
        if k % group_size != 0:
            raise ValueError(f"k={k} must be a multiple of group_size={group_size}")
        if self.ordering not in ("strided", "contiguous"):
            raise ValueError(f"unknown ordering {self.ordering!r}")


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def dequant_block(qw_blk, scale_blk, qz_blk, block_k: int, block_n: int,
                  compute_dtype=jnp.float32):
    """Dequantize one packed VMEM block.

    ``qw_blk``  int32 [block_k//8, block_n]  (packed along k)
    ``scale_blk`` float [1, block_n]
    ``qz_blk``  int32 [1, block_n//8]        (packed along n)
    returns ``compute_dtype`` [block_k, block_n].
    """
    shifts_k = (4 * jnp.arange(PACK_FACTOR, dtype=jnp.int32)).reshape(1, PACK_FACTOR, 1)
    q = ((qw_blk[:, None, :] >> shifts_k) & 0xF).reshape(block_k, block_n)
    shifts_n = (4 * jnp.arange(PACK_FACTOR, dtype=jnp.int32)).reshape(1, 1, PACK_FACTOR)
    z = ((qz_blk[:, :, None] >> shifts_n) & 0xF).reshape(1, block_n)
    b = (q.astype(jnp.float32) - z.astype(jnp.float32)) * scale_blk.astype(jnp.float32)
    return b.astype(compute_dtype)
