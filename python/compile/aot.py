"""S6 — AOT exporter: lower L2/L1 to HLO **text** artifacts for Rust.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly (see
/opt/xla-example/gen_hlo.py and README gotchas).

Artifacts written to ``artifacts/``:

  * ``gemm_{variant}_m{M}_n{N}_k{K}.hlo.txt`` — the standalone fused
    W4A16 GEMM (runtime inputs: a, qweight, scales, qzeros) for
    variant ∈ {splitk, dp}, M ∈ {1, 16}, N = K ∈ GEMM_SIZES.
  * ``decode_{variant}_b{B}.hlo.txt`` — one decode step of the tiny llama
    model at batch bucket B (weights baked in as HLO constants; runtime
    inputs: tokens, kv_cache, pos).
  * ``manifest.json`` — input/output specs + model/kernel metadata the
    Rust runtime uses to drive the executables.

Python runs ONLY here (``make artifacts``); never on the request path.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
from typing import Any

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels import KernelConfig
from .model import (ModelConfig, decode_step, gemm_fn, init_kv_cache,
                    init_params, kv_cache_shape)

GEMM_SIZES = (512, 1024, 2048)
GEMM_SIZES_FULL = (512, 1024, 2048, 4096)
GEMM_MS = (1, 16)
BATCH_BUCKETS = (1, 2, 4, 8, 16)
GEMM_GROUP_SIZE = 128


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser).

    Printed with ``print_large_constants=True`` — the default printer
    elides big constants as ``{...}``, which the Rust-side text parser
    silently reads back as zeros (all baked weights would vanish).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # This jax's metadata includes source_end_line/column attributes the
    # xla_extension 0.5.1 text parser rejects — strip metadata entirely.
    opts.print_metadata = False
    return comp.get_hlo_module().to_string(opts)


def _spec(shape, dtype) -> dict[str, Any]:
    return {"shape": list(shape), "dtype": str(jnp.dtype(dtype))}


def export_gemm(out_dir: str, variant: str, m: int, n: int, k: int,
                group_size: int, config: KernelConfig) -> dict[str, Any]:
    fn = gemm_fn(variant, group_size, config)
    specs = (
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((k // 8, n), jnp.int32),
        jax.ShapeDtypeStruct((k // group_size, n), jnp.float32),
        jax.ShapeDtypeStruct((k // group_size, n // 8), jnp.int32),
    )
    lowered = jax.jit(fn).lower(*specs)
    name = f"gemm_{variant}_m{m}_n{n}_k{k}"
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return {
        "name": name,
        "kind": "gemm",
        "file": os.path.basename(path),
        "variant": variant,
        "m": m, "n": n, "k": k,
        "group_size": group_size,
        "kernel_config": {
            "block_m": min(config.block_m, m), "block_n": config.block_n,
            "block_k": config.block_k,
            "split_k": config.split_k if variant == "splitk" else 1,
            "ordering": config.ordering,
        },
        "inputs": [
            {"name": "a", **_spec((m, k), jnp.float32)},
            {"name": "qweight", **_spec((k // 8, n), jnp.int32)},
            {"name": "scales", **_spec((k // group_size, n), jnp.float32)},
            {"name": "qzeros", **_spec((k // group_size, n // 8), jnp.int32)},
        ],
        "outputs": [{"name": "c", **_spec((m, n), jnp.float32)}],
        "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
    }


def export_decode(out_dir: str, cfg: ModelConfig, params, batch: int) -> dict[str, Any]:
    def fn(tokens, kv, pos, start):
        return decode_step(params, cfg, tokens, kv, pos, start)

    kv_shape = kv_cache_shape(cfg, batch)
    specs = (
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        jax.ShapeDtypeStruct(kv_shape, jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
    )
    # Donate the KV cache: XLA aliases the input buffer for the output
    # cache, removing a device-side copy of the largest tensor on the
    # decode hot path (§Perf L2 iteration).
    lowered = jax.jit(fn, donate_argnums=(1,)).lower(*specs)
    name = f"decode_{cfg.variant}_b{batch}"
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return {
        "name": name,
        "kind": "decode",
        "file": os.path.basename(path),
        "variant": cfg.variant,
        "batch": batch,
        "inputs": [
            {"name": "tokens", **_spec((batch,), jnp.int32)},
            {"name": "kv_cache", **_spec(kv_shape, jnp.float32)},
            {"name": "pos", **_spec((), jnp.int32)},
            {"name": "start", **_spec((batch,), jnp.int32)},
        ],
        "outputs": [
            {"name": "logits", **_spec((batch, cfg.vocab), jnp.float32)},
            {"name": "kv_cache", **_spec(kv_shape, jnp.float32)},
        ],
        "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--full", action="store_true",
                    help="also export the n=k=4096 GEMM artifacts")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-decode", action="store_true",
                    help="only export the GEMM artifacts (fast)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    entries: list[dict[str, Any]] = []
    sizes = GEMM_SIZES_FULL if args.full else GEMM_SIZES
    for variant in ("splitk", "dp"):
        for m in GEMM_MS:
            for nk in sizes:
                # Size-dependent tiles (§Perf L1 iterations 2-3): time on
                # the interpret-lowered CPU path is ~linear in grid-step
                # count, so target <= ~32 steps: block_n = nk/4 (capped at
                # 512), block_k = 128 (the group-size ceiling). VMEM
                # estimate per step at the largest tile (16x512 out,
                # 128x512 packed+dequant) is ~0.8 MB double-buffered —
                # comfortably inside a real TPU's ~16 MB VMEM; see
                # EXPERIMENTS.md §Perf for the measured sweep.
                block_n = min(max(nk // 4, 64), 512)
                block_k = 128 if nk >= 1024 else 64
                config = KernelConfig(block_m=m, block_n=block_n,
                                      block_k=block_k,
                                      split_k=4 if variant == "splitk" else 1)
                e = export_gemm(args.out, variant, m, nk, nk,
                                GEMM_GROUP_SIZE, config)
                entries.append(e)
                print(f"exported {e['name']} ({e['sha256']})")

    model_cfg = ModelConfig()
    if not args.skip_decode:
        params = init_params(model_cfg, seed=args.seed)
        for b in BATCH_BUCKETS:
            e = export_decode(args.out, model_cfg, params, b)
            entries.append(e)
            print(f"exported {e['name']} ({e['sha256']})")

    manifest = {
        "format": 1,
        "model": {
            "vocab": model_cfg.vocab,
            "d_model": model_cfg.d_model,
            "n_layers": model_cfg.n_layers,
            "n_heads": model_cfg.n_heads,
            "d_ff": model_cfg.d_ff,
            "max_seq": model_cfg.max_seq,
            "group_size": model_cfg.group_size,
            "variant": model_cfg.variant,
            "batch_buckets": list(BATCH_BUCKETS),
            "seed": args.seed,
        },
        "artifacts": entries,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(entries)} artifacts to {args.out}")


if __name__ == "__main__":
    main()
