"""L2 building blocks: llama-style layers with W4A16 quantized linears.

Every projection (qkv, attention output, SwiGLU gate/up/down, lm head) runs
through the fused Pallas W4A16 kernel, so a decode step of the model is a
sequence of exactly the skinny ``m = batch`` GEMMs the paper targets.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

from .kernels import KernelConfig, w4a16_gemm_dp, w4a16_gemm_splitk


@dataclasses.dataclass(frozen=True)
class QuantLinearParams:
    """Packed parameters of one W4A16 linear layer ``[k_in, n_out]``."""

    qweight: jax.Array  # int32 [k//8, n]
    scales: jax.Array   # f32   [k//group, n]
    qzeros: jax.Array   # int32 [k//group, n//8]

    @property
    def k(self) -> int:
        return self.qweight.shape[0] * 8

    @property
    def n(self) -> int:
        return self.qweight.shape[1]


def quant_linear(x: jax.Array, p: QuantLinearParams, *, group_size: int,
                 config: KernelConfig,
                 variant: Literal["splitk", "dp"] = "splitk") -> jax.Array:
    """``x [m, k] @ dequant(p) [k, n] -> [m, n]`` via the fused kernel."""
    fn = w4a16_gemm_splitk if variant == "splitk" else w4a16_gemm_dp
    return fn(x, p.qweight, p.scales, p.qzeros, group_size=group_size,
              config=config, out_dtype=x.dtype)


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm over the last axis (llama-style, no bias)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    normed = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (normed * weight).astype(x.dtype)


def rope_angles(head_dim: int, max_seq: int, base: float = 10000.0):
    """Precomputed RoPE cos/sin tables ``[max_seq, head_dim//2]``."""
    inv_freq = 1.0 / (base ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                               / head_dim))
    t = jnp.arange(max_seq, dtype=jnp.float32)
    freqs = jnp.outer(t, inv_freq)
    return jnp.cos(freqs), jnp.sin(freqs)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Rotate ``x [..., head_dim]`` by position-specific cos/sin
    ``[..., head_dim//2]`` (broadcastable)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    """SwiGLU activation: ``silu(gate) * up``."""
    return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


def attention_decode(q, k_new, v_new, k_cache, v_cache, pos, start=None):
    """Single-token attention against a static-shape KV cache.

    q, k_new, v_new: ``[b, h, hd]`` for the current position.
    k_cache, v_cache: ``[b, h, max_seq, hd]``.
    pos: scalar int32, the index being written this step.
    start: optional int32 ``[b]`` — first valid position per sequence.
      The Rust batcher left-pads unequal prompts to a common length; pad
      positions (< start) are masked out of attention so batching never
      changes a sequence's numerics.
    Returns (context ``[b, h, hd]``, new k_cache, new v_cache).
    """
    b, h, hd = q.shape
    max_seq = k_cache.shape[2]
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k_new[:, :, None, :], (0, 0, pos, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v_new[:, :, None, :], (0, 0, pos, 0))
    scores = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) / jnp.sqrt(float(hd))
    positions = jnp.arange(max_seq)
    mask = positions[None, :] <= pos  # causal: only written positions
    if start is not None:
        mask = jnp.logical_and(mask, positions[None, :] >= start[:, None])
    else:
        mask = jnp.broadcast_to(mask, (b, max_seq))
    scores = jnp.where(mask[:, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhs,bhsd->bhd", probs, v_cache.astype(jnp.float32))
    return ctx.astype(q.dtype), k_cache, v_cache
