"""L2 — tiny llama-style decoder with W4A16 quantized projections (S5).

The model exists to put the paper's kernel on a *real* inference path: a
decode step at batch ``b`` issues exactly the skinny ``m = b`` GEMMs
(qkv / attn-out / gate / up / down / lm-head) the paper benchmarks.

Weights are random-initialized then GPTQ-style quantized by
``compile.quant`` (no pretrained checkpoint is available in this
environment — substitution documented in DESIGN.md §2). ``aot.py`` bakes
the quantized weights into the exported HLO as constants, so the Rust
engine's runtime inputs are only ``(tokens, kv_cache, pos)``.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from . import quant
from .kernels import KernelConfig
from .layers import (QuantLinearParams, apply_rope, attention_decode,
                     quant_linear, rms_norm, rope_angles, swiglu)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture + quantization + kernel-launch configuration."""

    vocab: int = 512
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 512
    max_seq: int = 128
    group_size: int = 64
    rope_base: float = 10000.0
    variant: Literal["splitk", "dp"] = "splitk"
    block_n: int = 64
    block_k: int = 64
    split_k: int = 4

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def kernel_config(self, m: int) -> KernelConfig:
        return KernelConfig(block_m=max(m, 1), block_n=self.block_n,
                            block_k=self.block_k, split_k=self.split_k)


@dataclasses.dataclass(frozen=True)
class LayerParams:
    attn_norm: jax.Array
    wq: QuantLinearParams
    wk: QuantLinearParams
    wv: QuantLinearParams
    wo: QuantLinearParams
    mlp_norm: jax.Array
    w_gate: QuantLinearParams
    w_up: QuantLinearParams
    w_down: QuantLinearParams


@dataclasses.dataclass(frozen=True)
class ModelParams:
    embed: jax.Array  # f32 [vocab, d_model] (not quantized, like GPTQ llama)
    layers: tuple[LayerParams, ...]
    final_norm: jax.Array
    lm_head: QuantLinearParams  # W4A16 [d_model, vocab]


def _quantize(rng: np.random.Generator, k: int, n: int, group_size: int,
              scale: float) -> QuantLinearParams:
    qw, s, qz, _ = quant.random_quantized_weight(rng, k, n, group_size, scale)
    return QuantLinearParams(jnp.asarray(qw), jnp.asarray(s), jnp.asarray(qz))


def init_params(cfg: ModelConfig, seed: int = 0) -> ModelParams:
    """Random-init weights, GPTQ-quantize every projection."""
    rng = np.random.default_rng(seed)
    d, f, v, g = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.group_size
    scale = 1.0 / np.sqrt(d)
    layers = []
    for _ in range(cfg.n_layers):
        layers.append(LayerParams(
            attn_norm=jnp.ones((d,), jnp.float32),
            wq=_quantize(rng, d, d, g, scale),
            wk=_quantize(rng, d, d, g, scale),
            wv=_quantize(rng, d, d, g, scale),
            wo=_quantize(rng, d, d, g, scale),
            mlp_norm=jnp.ones((d,), jnp.float32),
            w_gate=_quantize(rng, d, f, g, scale),
            w_up=_quantize(rng, d, f, g, scale),
            w_down=_quantize(rng, f, d, g, 1.0 / np.sqrt(f)),
        ))
    embed = jnp.asarray(
        rng.standard_normal((v, d), dtype=np.float32) * 0.02)
    return ModelParams(
        embed=embed,
        layers=tuple(layers),
        final_norm=jnp.ones((d,), jnp.float32),
        lm_head=_quantize(rng, d, v, g, scale),
    )


def kv_cache_shape(cfg: ModelConfig, batch: int) -> tuple[int, ...]:
    """Shape of the stacked KV cache: ``[layers, 2, b, heads, max_seq, hd]``."""
    return (cfg.n_layers, 2, batch, cfg.n_heads, cfg.max_seq, cfg.head_dim)


def init_kv_cache(cfg: ModelConfig, batch: int) -> jax.Array:
    return jnp.zeros(kv_cache_shape(cfg, batch), jnp.float32)


def decode_step(params: ModelParams, cfg: ModelConfig, tokens: jax.Array,
                kv_cache: jax.Array, pos: jax.Array, start=None):
    """One decode step for a batch of sequences at the same position.

    tokens:   int32 ``[b]`` — current token per sequence.
    kv_cache: f32 ``[layers, 2, b, h, max_seq, hd]``.
    pos:      scalar int32 — position the step writes (same for the batch;
              the Rust batcher left-pads prompts to a common length).
    start:    optional int32 ``[b]`` — first valid position per sequence;
              positions before it are padding and masked from attention.
    Returns ``(logits [b, vocab], new_kv_cache)``.
    """
    b = tokens.shape[0]
    kc = cfg.kernel_config(b)
    h, hd = cfg.n_heads, cfg.head_dim

    x = params.embed[tokens]  # [b, d]
    cos_t, sin_t = rope_angles(hd, cfg.max_seq, cfg.rope_base)
    cos = jax.lax.dynamic_slice_in_dim(cos_t, pos, 1, axis=0)  # [1, hd/2]
    sin = jax.lax.dynamic_slice_in_dim(sin_t, pos, 1, axis=0)

    new_kv = []
    for li, lp in enumerate(params.layers):
        xn = rms_norm(x, lp.attn_norm)
        q = quant_linear(xn, lp.wq, group_size=cfg.group_size, config=kc,
                         variant=cfg.variant).reshape(b, h, hd)
        k = quant_linear(xn, lp.wk, group_size=cfg.group_size, config=kc,
                         variant=cfg.variant).reshape(b, h, hd)
        v = quant_linear(xn, lp.wv, group_size=cfg.group_size, config=kc,
                         variant=cfg.variant).reshape(b, h, hd)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        ctx, k_cache, v_cache = attention_decode(
            q, k, v, kv_cache[li, 0], kv_cache[li, 1], pos, start)
        new_kv.append(jnp.stack([k_cache, v_cache], axis=0))
        attn_out = quant_linear(ctx.reshape(b, h * hd), lp.wo,
                                group_size=cfg.group_size, config=kc,
                                variant=cfg.variant)
        x = x + attn_out
        xn = rms_norm(x, lp.mlp_norm)
        gate = quant_linear(xn, lp.w_gate, group_size=cfg.group_size,
                            config=kc, variant=cfg.variant)
        up = quant_linear(xn, lp.w_up, group_size=cfg.group_size, config=kc,
                          variant=cfg.variant)
        down = quant_linear(swiglu(gate, up), lp.w_down,
                            group_size=cfg.group_size, config=kc,
                            variant=cfg.variant)
        x = x + down

    xn = rms_norm(x, params.final_norm)
    logits = quant_linear(xn, params.lm_head, group_size=cfg.group_size,
                          config=kc, variant=cfg.variant)
    return logits, jnp.stack(new_kv, axis=0)


def gemm_fn(variant: str, group_size: int, config: KernelConfig):
    """Standalone fused-GEMM entry point used for the GEMM artifacts."""
    from .kernels import w4a16_gemm_dp, w4a16_gemm_splitk

    fn = w4a16_gemm_splitk if variant == "splitk" else w4a16_gemm_dp

    def run(a, qweight, scales, qzeros):
        return fn(a, qweight, scales, qzeros, group_size=group_size,
                  config=config)

    return run
